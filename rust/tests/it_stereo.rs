//! Integration: stereo rasterization across datasets, poses and tile
//! sizes — the §4.4 guarantees at system scale.

use nebula::benchkit;
use nebula::math::{Intrinsics, StereoCamera};
use nebula::render::raster::RasterConfig;
use nebula::render::sort::sort_splats;
use nebula::render::stereo::{
    render_right_naive, render_stereo_from_splats, StereoMode,
};
use nebula::render::preprocess_records;
use nebula::scene::{dataset, CityGen};

fn shared_set(
    cam: &StereoCamera,
    queue: &[(u32, nebula::gaussian::GaussianRecord)],
) -> nebula::render::ProjectedSet {
    let refs = benchkit::queue_refs(queue);
    let left = cam.left();
    let shared = cam.shared_camera();
    let mut set = preprocess_records(&left, &shared, &refs, 3);
    sort_splats(&mut set.splats);
    set
}

#[test]
fn exact_mode_bitwise_across_datasets_and_tiles() {
    for name in ["tnt", "urban"] {
        let spec = dataset(name).unwrap();
        let tree = CityGen::new(spec.city_params(15_000)).build();
        let pl = benchkit::calibrated_pipeline(&tree, &spec);
        for (fi, pose) in benchkit::walk_trace(&spec, 40).iter().step_by(13).enumerate() {
            for tile in [8u32, 16, 32] {
                let cam = StereoCamera::new(*pose, Intrinsics::vr_eye_scaled(16));
                let cut = benchkit::cut_at(&tree, pose, &pl);
                let queue = benchkit::queue_for(&tree, &cut);
                let set = shared_set(&cam, &queue);
                let cfg = RasterConfig::default();
                let (naive, _) = render_right_naive(&cam, &set, tile, &cfg);
                let out = render_stereo_from_splats(&cam, &set, tile, &cfg, StereoMode::Exact);
                assert_eq!(
                    out.right.data, naive.data,
                    "{name} frame#{fi} tile={tile}: Exact mode not bitwise"
                );
            }
        }
    }
}

#[test]
fn alpha_gated_quality_and_savings() {
    let spec = dataset("m360").unwrap();
    let tree = CityGen::new(spec.city_params(30_000)).build();
    let pl = benchkit::calibrated_pipeline(&tree, &spec);
    let pose = benchkit::walk_trace(&spec, 10)[9];
    let cam = StereoCamera::new(pose, Intrinsics::vr_eye_scaled(8));
    let cut = benchkit::cut_at(&tree, &pose, &pl);
    let queue = benchkit::queue_for(&tree, &cut);
    let set = shared_set(&cam, &queue);
    let cfg = RasterConfig::default();
    let (naive, naive_stats) = render_right_naive(&cam, &set, 16, &cfg);
    let out = render_stereo_from_splats(&cam, &set, 16, &cfg, StereoMode::AlphaGated);
    let psnr = out.right.psnr(&naive);
    assert!(psnr > 40.0, "AlphaGated PSNR {psnr:.1}");
    assert!(
        out.stats_right.pairs < naive_stats.pairs,
        "gating must prune right-eye work: {} vs {}",
        out.stats_right.pairs,
        naive_stats.pairs
    );
}

#[test]
fn stereo_shares_preprocessing_work() {
    // The §4.4 point: one preprocess+sort for two eyes, and the right
    // eye's raster work is lower than the left's.
    let spec = dataset("db").unwrap();
    let tree = CityGen::new(spec.city_params(20_000)).build();
    let pl = benchkit::calibrated_pipeline(&tree, &spec);
    let pose = benchkit::walk_trace(&spec, 5)[4];
    let cam = StereoCamera::new(pose, Intrinsics::vr_eye_scaled(16));
    let cut = benchkit::cut_at(&tree, &pose, &pl);
    let queue = benchkit::queue_for(&tree, &cut);
    let set = shared_set(&cam, &queue);
    let n_preprocessed = set.splats.len();
    let out = render_stereo_from_splats(&cam, &set, 16, &RasterConfig::default(), StereoMode::AlphaGated);
    assert_eq!(out.preprocessed, n_preprocessed, "single shared preprocess");
    assert!(out.stats_right.pairs <= out.stats_left.pairs);
    // Workload accounting sees the sharing.
    let wl = nebula::hw::FrameWorkload::from_stereo(&out, 1 << 20);
    assert!(wl.shared_preproc);
    assert_eq!(wl.preprocessed, n_preprocessed as u64);
}

#[test]
fn disparity_lists_bounded_by_l() {
    let spec = dataset("tnt").unwrap();
    let tree = CityGen::new(spec.city_params(10_000)).build();
    let pl = benchkit::calibrated_pipeline(&tree, &spec);
    let pose = benchkit::walk_trace(&spec, 3)[2];
    let cam = StereoCamera::new(pose, Intrinsics::vr_eye_scaled(16));
    let cut = benchkit::cut_at(&tree, &pose, &pl);
    let queue = benchkit::queue_for(&tree, &cut);
    let set = shared_set(&cam, &queue);
    let out = render_stereo_from_splats(&cam, &set, 16, &RasterConfig::default(), StereoMode::Exact);
    assert_eq!(out.num_lists, 4, "paper's four disparity categories");
    assert!(out.max_disparity_px <= 48.0 + 1e-6);
}
