//! Integration: stereo rasterization across datasets, poses and tile
//! sizes — the §4.4 guarantees at system scale.

use nebula::benchkit;
use nebula::math::{Intrinsics, StereoCamera};
use nebula::render::raster::RasterConfig;
use nebula::render::sort::sort_splats;
use nebula::render::stereo::{
    render_right_naive, render_stereo_from_splats, StereoMode,
};
use nebula::render::{preprocess_records, Parallelism};
use nebula::scene::{dataset, CityGen};

fn shared_set(
    cam: &StereoCamera,
    queue: &[(u32, nebula::gaussian::GaussianRecord)],
) -> nebula::render::ProjectedSet {
    let refs = benchkit::queue_refs(queue);
    let left = cam.left();
    let shared = cam.shared_camera();
    let mut set = preprocess_records(&left, &shared, &refs, 3, Parallelism::auto());
    sort_splats(&mut set.splats);
    set
}

#[test]
fn exact_mode_bitwise_across_datasets_and_tiles() {
    for name in ["tnt", "urban"] {
        let spec = dataset(name).unwrap();
        let tree = CityGen::new(spec.city_params(15_000)).build();
        let pl = benchkit::calibrated_pipeline(&tree, &spec);
        for (fi, pose) in benchkit::walk_trace(&spec, 40).iter().step_by(13).enumerate() {
            for tile in [8u32, 16, 32] {
                let cam = StereoCamera::new(*pose, Intrinsics::vr_eye_scaled(16));
                let cut = benchkit::cut_at(&tree, pose, &pl);
                let queue = benchkit::queue_for(&tree, &cut);
                let set = shared_set(&cam, &queue);
                let cfg = RasterConfig::default();
                let (naive, _) = render_right_naive(&cam, &set, tile, &cfg);
                let out = render_stereo_from_splats(&cam, &set, tile, &cfg, StereoMode::Exact);
                assert_eq!(
                    out.right.data, naive.data,
                    "{name} frame#{fi} tile={tile}: Exact mode not bitwise"
                );
            }
        }
    }
}

#[test]
fn alpha_gated_quality_and_savings() {
    let spec = dataset("m360").unwrap();
    let tree = CityGen::new(spec.city_params(30_000)).build();
    let pl = benchkit::calibrated_pipeline(&tree, &spec);
    let pose = benchkit::walk_trace(&spec, 10)[9];
    let cam = StereoCamera::new(pose, Intrinsics::vr_eye_scaled(8));
    let cut = benchkit::cut_at(&tree, &pose, &pl);
    let queue = benchkit::queue_for(&tree, &cut);
    let set = shared_set(&cam, &queue);
    let cfg = RasterConfig::default();
    let (naive, naive_stats) = render_right_naive(&cam, &set, 16, &cfg);
    let out = render_stereo_from_splats(&cam, &set, 16, &cfg, StereoMode::AlphaGated);
    let psnr = out.right.psnr(&naive);
    assert!(psnr > 40.0, "AlphaGated PSNR {psnr:.1}");
    assert!(
        out.stats_right.pairs < naive_stats.pairs,
        "gating must prune right-eye work: {} vs {}",
        out.stats_right.pairs,
        naive_stats.pairs
    );
}

#[test]
fn stereo_shares_preprocessing_work() {
    // The §4.4 point: one preprocess+sort for two eyes, and the right
    // eye's raster work is lower than the left's.
    let spec = dataset("db").unwrap();
    let tree = CityGen::new(spec.city_params(20_000)).build();
    let pl = benchkit::calibrated_pipeline(&tree, &spec);
    let pose = benchkit::walk_trace(&spec, 5)[4];
    let cam = StereoCamera::new(pose, Intrinsics::vr_eye_scaled(16));
    let cut = benchkit::cut_at(&tree, &pose, &pl);
    let queue = benchkit::queue_for(&tree, &cut);
    let set = shared_set(&cam, &queue);
    let n_preprocessed = set.splats.len();
    let out = render_stereo_from_splats(&cam, &set, 16, &RasterConfig::default(), StereoMode::AlphaGated);
    assert_eq!(out.preprocessed, n_preprocessed, "single shared preprocess");
    assert!(out.stats_right.pairs <= out.stats_left.pairs);
    // Workload accounting sees the sharing.
    let wl = nebula::hw::FrameWorkload::from_stereo(&out, 1 << 20);
    assert!(wl.shared_preproc);
    assert_eq!(wl.preprocessed, n_preprocessed as u64);
}

#[test]
fn exact_mode_bitwise_on_random_splat_sets() {
    // The binning↔SRU mirror invariant at system level: for ARBITRARY
    // screen-space splat sets (on-screen, edge-straddling, off-screen in
    // the extended columns, fully off-grid), the merged right eye must
    // equal the naive re-bin of the shifted splats — bitwise — across
    // tile sizes and image widths that don't divide the tile (where the
    // tile grid overhangs the image and the clamps could drift apart).
    use nebula::render::sort::is_sorted;
    use nebula::render::{ProjectedSet, Splat};
    use nebula::util::prop::{check, Config};
    use nebula::math::{Pose, Vec2, Vec3};

    check("random-set Exact ≡ naive", Config { cases: 24, seed: 0x57_E0 }, |rng| {
        let tile = [4u32, 8, 16, 32][rng.below(4)];
        let w = 33 + rng.below(64) as u32; // rarely a tile multiple
        let h = 33 + rng.below(48) as u32;
        let cam = StereoCamera::new(
            Pose::looking(Vec3::new(0.0, 1.7, 0.0), 0.0, 0.0),
            Intrinsics::from_fov(w, h, 90f32.to_radians(), 0.1, 1000.0),
        );
        let n = rng.range_usize(0, 300);
        let mut splats: Vec<Splat> = (0..n)
            .map(|i| {
                let a = rng.range_f32(0.05, 1.5);
                let c = rng.range_f32(0.05, 1.5);
                let b_max = (a * c).sqrt() * 0.9;
                Splat {
                    id: i as u32,
                    mean: Vec2::new(
                        rng.range_f32(-24.0, w as f32 + 150.0),
                        rng.range_f32(-24.0, h as f32 + 24.0),
                    ),
                    conic: [a, rng.range_f32(-b_max, b_max), c],
                    depth: rng.range_f32(0.2, 90.0),
                    radius_px: rng.range_f32(1.0, 9.0).ceil(),
                    color: [rng.f32(), rng.f32(), rng.f32()],
                    opacity: rng.range_f32(0.05, 0.999),
                }
            })
            .collect();
        sort_splats(&mut splats);
        assert!(is_sorted(&splats));
        let set = ProjectedSet { splats, processed: n, culled: 0 };
        let cfg = RasterConfig::default();
        let (naive, _) = render_right_naive(&cam, &set, tile, &cfg);
        let out = render_stereo_from_splats(&cam, &set, tile, &cfg, StereoMode::Exact);
        assert_eq!(
            out.right.data, naive.data,
            "tile={tile} w={w} h={h} n={n}: SRU/merge diverged from naive re-bin"
        );
    });
}

#[test]
fn disparity_lists_bounded_by_l() {
    let spec = dataset("tnt").unwrap();
    let tree = CityGen::new(spec.city_params(10_000)).build();
    let pl = benchkit::calibrated_pipeline(&tree, &spec);
    let pose = benchkit::walk_trace(&spec, 3)[2];
    let cam = StereoCamera::new(pose, Intrinsics::vr_eye_scaled(16));
    let cut = benchkit::cut_at(&tree, &pose, &pl);
    let queue = benchkit::queue_for(&tree, &cut);
    let set = shared_set(&cam, &queue);
    let out = render_stereo_from_splats(&cam, &set, 16, &RasterConfig::default(), StereoMode::Exact);
    assert_eq!(out.num_lists, 4, "paper's four disparity categories");
    assert!(out.max_disparity_px <= 48.0 + 1e-6);
}
