//! Fig 16: stereo rendering quality — Base vs WARP vs Cicero vs Nebula
//! (PSNR / SSIM / LPIPS-proxy of the synthesized right eye against the
//! pipeline's right-eye reference), averaged over datasets.

use nebula::benchkit::{self, build_scene, walk_trace};
use nebula::math::{Intrinsics, StereoCamera};
use nebula::render::raster::{render_bins, RasterConfig};
use nebula::render::sort::sort_splats_par;
use nebula::render::stereo::{render_right_naive, render_stereo_from_splats, StereoMode};
use nebula::render::warp::{depth_map, warp_right, WarpKind};
use nebula::render::{preprocess_records, Parallelism, TileBins};
use nebula::scene::ALL_DATASETS;
use nebula::util::bench::bench_header;
use nebula::util::table::{fnum, Table};

fn main() {
    bench_header("Fig 16", "stereo quality: Base / WARP / Cicero / Nebula");
    let mut agg = vec![(0.0f64, 0.0f64, 0.0f64); 4]; // psnr, ssim, lpips per method
    let methods = ["WARP", "Cicero-proxy", "Nebula-AlphaGated", "Nebula-Exact"];
    let mut n = 0.0;

    for spec in ALL_DATASETS {
        let tree = build_scene(&spec);
        let pl = benchkit::calibrated_pipeline(&tree, &spec);
        let pose = walk_trace(&spec, 20)[19];
        let cam = StereoCamera::new(pose, Intrinsics::vr_eye_scaled(16));
        let cut = benchkit::cut_at(&tree, &pose, &pl);
        let queue = benchkit::queue_for(&tree, &cut);
        let left_cam = cam.left();
        let mut set =
            preprocess_records(&left_cam, &cam.shared_camera(), &benchkit::queue_refs(&queue), 3, Parallelism::auto());
        sort_splats_par(&mut set.splats, Parallelism::auto());
        let cfg = RasterConfig::default();
        let (reference, _) = render_right_naive(&cam, &set, pl.tile, &cfg);

        let bins = TileBins::build_par(
            cam.intr.width,
            cam.intr.height,
            pl.tile,
            0,
            &set.splats,
            Parallelism::auto(),
        );
        let (left_img, _, _) =
            render_bins(&set.splats, &bins, cam.intr.width, cam.intr.height, &cfg);
        let depth =
            depth_map(&set.splats, &bins, cam.intr.width, cam.intr.height, &cfg, cam.intr.far);

        let images = [
            warp_right(&left_img, &depth, &cam, WarpKind::Warp),
            warp_right(&left_img, &depth, &cam, WarpKind::Cicero),
            render_stereo_from_splats(&cam, &set, pl.tile, &cfg, StereoMode::AlphaGated).right,
            render_stereo_from_splats(&cam, &set, pl.tile, &cfg, StereoMode::Exact).right,
        ];
        for (i, img) in images.iter().enumerate() {
            agg[i].0 += img.psnr(&reference);
            agg[i].1 += img.ssim(&reference);
            agg[i].2 += img.lpips_proxy(&reference);
        }
        n += 1.0;
    }

    let mut t = Table::new(vec!["method", "PSNR dB", "SSIM", "LPIPS-proxy"]);
    t.row(vec!["Base (reference)".into(), "99.0".to_string(), "1.0000".into(), "0.0000".into()]);
    for (i, m) in methods.iter().enumerate() {
        t.row(vec![
            m.to_string(),
            fnum(agg[i].0 / n, 1),
            fnum(agg[i].1 / n, 4),
            fnum(agg[i].2 / n, 4),
        ]);
    }
    t.print();
    println!("paper: warping methods lose quality; Nebula is ~lossless (Exact = bitwise).");
}
