//! BENCH_multiclient: the multi-session cloud server swept over
//! clients × threads. Writes `BENCH_multiclient.json` with a
//! `"multiclient"` section: per configuration the wall ms for the whole
//! trace, simulated session-frames/s, aggregate cloud LoD visits/s,
//! mean/max per-client p99 MTP, shared-uplink utilization, cloud-budget
//! utilization, and fairness (max/mean per-client MTP).
//!
//!     cargo bench --bench bench_multiclient [-- --smoke]
//!
//! `--smoke` is the CI canary: a minimal scene and a {1,4} × {1,2}
//! sweep, but every parity assertion still executes:
//! * clients = 1 with the default ServerConfig reproduces the legacy
//!   single-client `run_simulation` SimResult field-for-field;
//! * every clients value yields bitwise-identical per-client results at
//!   every thread count (the across-session determinism discipline);
//! * aggregate cloud visits/s grows with the client count.
//!
//! Env knobs: `NEBULA_BENCH_SCALE` (scene divisor, default 8),
//! `NEBULA_BENCH_OUT` (output path, default `BENCH_multiclient.json`).

use nebula::benchkit;
use nebula::coordinator::scheduler::{run_simulation, SimParams};
use nebula::coordinator::{run_multiclient, MulticlientResult, ServerConfig, Variant};
use nebula::scene::{dataset, CityGen};
use nebula::util::bench::bench_header;
use nebula::util::Stopwatch;

struct Row {
    clients: usize,
    threads: usize,
    wall_ms: f64,
    session_frames_per_s: f64,
    aggregate_visits_per_s: f64,
    mean_p99_mtp_ms: f64,
    max_p99_mtp_ms: f64,
    uplink_utilization: f64,
    cloud_utilization: f64,
    fairness: f64,
}

fn p99_stats(r: &MulticlientResult) -> (f64, f64) {
    let mut mean = 0.0f64;
    let mut max = f64::NEG_INFINITY;
    for c in &r.per_client {
        mean += c.mtp_p99_ms;
        max = max.max(c.mtp_p99_ms);
    }
    (mean / r.per_client.len().max(1) as f64, max)
}

fn main() {
    bench_header("BENCH_multiclient", "multi-session cloud server, clients x threads sweep");
    let smoke = std::env::args().any(|a| a == "--smoke");
    if smoke {
        println!("smoke mode: minimal scene, {{1,4}} clients x {{1,2}} threads");
    }
    let spec = dataset("urban").unwrap();
    let target = (spec.sim_gaussians / benchkit::bench_scale() / if smoke { 4 } else { 1 })
        .max(10_000);
    let tree = CityGen::new(spec.city_params(target)).build();
    let mut params = SimParams::default();
    params.pipeline = benchkit::calibrated_pipeline(&tree, &spec);
    params.pipeline.res_scale = 16;
    let frames = if smoke { 12 } else { 48 };
    let clients_sweep: Vec<usize> = if smoke { vec![1, 4] } else { vec![1, 4, 16, 64] };
    let threads_sweep: Vec<usize> = if smoke { vec![1, 2] } else { vec![1, 2, 4, 8] };
    // Finite shared budgets so the contended paths are what's measured:
    // one A100-class cloud and a 1 Gbps egress for everyone.
    let server = ServerConfig { cloud_budget: 1.0, uplink_bps: 1e9, ..ServerConfig::default() };
    println!(
        "scene: {} Gaussians, {frames}-frame traces, cloud budget {:.1} A100, uplink 1 Gbps",
        tree.len(),
        server.cloud_budget
    );

    // --- Parity canary: N=1 + default config == legacy scheduler ------
    let traces1 = benchkit::walk_traces(&spec, frames, 1);
    params.pipeline.threads = 1;
    let legacy = run_simulation(&tree, &traces1[0], &Variant::nebula(), &params);
    let n1 =
        run_multiclient(&tree, &traces1, &Variant::nebula(), &params, &ServerConfig::default());
    assert_eq!(
        n1.per_client[0], legacy,
        "PARITY VIOLATION: N=1 CloudServer differs from the single-client scheduler"
    );
    println!("  parity: N=1 server == legacy scheduler (field-for-field)");

    let mut rows: Vec<Row> = Vec::new();
    let mut visits_by_clients: Vec<f64> = Vec::new();
    for &clients in &clients_sweep {
        let traces = benchkit::walk_traces(&spec, frames, clients);
        let mut reference: Option<MulticlientResult> = None;
        for &t in &threads_sweep {
            params.pipeline.threads = t;
            let start = Stopwatch::start();
            let r = run_multiclient(&tree, &traces, &Variant::nebula(), &params, &server);
            let wall_ms = start.elapsed_ms();
            if let Some(r0) = &reference {
                assert_eq!(
                    r.per_client, r0.per_client,
                    "PARITY VIOLATION: clients={clients} diverged at {t} threads"
                );
            } else {
                visits_by_clients.push(r.aggregate_visits_per_s);
                reference = Some(r.clone());
            }
            let (mean_p99, max_p99) = p99_stats(&r);
            println!(
                "  clients {clients:>3} t{t}: {wall_ms:>8.1} ms wall, {:>10.0} visits/s, \
                 p99 {mean_p99:>6.2}/{max_p99:>6.2} ms, uplink {:>5.1}%, cloud {:>5.1}%, \
                 fairness {:.3}",
                r.aggregate_visits_per_s,
                r.uplink_utilization * 100.0,
                r.cloud_utilization * 100.0,
                r.fairness
            );
            rows.push(Row {
                clients,
                threads: t,
                wall_ms,
                session_frames_per_s: (clients * frames) as f64 / (wall_ms * 1e-3),
                aggregate_visits_per_s: r.aggregate_visits_per_s,
                mean_p99_mtp_ms: mean_p99,
                max_p99_mtp_ms: max_p99,
                uplink_utilization: r.uplink_utilization,
                cloud_utilization: r.cloud_utilization,
                fairness: r.fairness,
            });
        }
    }

    // --- Scaling canary: more clients must mean more cloud work -------
    for w in visits_by_clients.windows(2) {
        assert!(
            w[1] > w[0],
            "CANARY: aggregate visits/s must grow with the client count ({} -> {})",
            w[0],
            w[1]
        );
    }

    // --- JSON (hand-rolled; serde unavailable offline) -----------------
    let mut j = String::new();
    j.push_str("{\n");
    j.push_str("  \"bench\": \"multiclient\",\n");
    j.push_str(&format!(
        "  \"scene\": {{\"dataset\": \"{}\", \"target_gaussians\": {target}, \"frames\": {frames}}},\n",
        spec.name
    ));
    j.push_str(&format!(
        "  \"server\": {{\"cloud_budget\": {:.3}, \"uplink_bps\": {:.0}}},\n",
        server.cloud_budget, server.uplink_bps
    ));
    j.push_str("  \"multiclient\": [\n");
    for (i, r) in rows.iter().enumerate() {
        j.push_str(&format!(
            "    {{\"clients\": {}, \"threads\": {}, \"wall_ms\": {:.3}, \"session_frames_per_s\": {:.1}, \"aggregate_visits_per_s\": {:.0}, \"mean_p99_mtp_ms\": {:.4}, \"max_p99_mtp_ms\": {:.4}, \"uplink_utilization\": {:.6}, \"cloud_utilization\": {:.6}, \"fairness\": {:.4}}}{}\n",
            r.clients,
            r.threads,
            r.wall_ms,
            r.session_frames_per_s,
            r.aggregate_visits_per_s,
            r.mean_p99_mtp_ms,
            r.max_p99_mtp_ms,
            r.uplink_utilization,
            r.cloud_utilization,
            r.fairness,
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    j.push_str("  ]\n}\n");

    let out_path = std::env::var("NEBULA_BENCH_OUT")
        .unwrap_or_else(|_| "BENCH_multiclient.json".to_string());
    std::fs::write(&out_path, &j).expect("write bench json");
    println!("wrote {out_path}");
}
