//! BENCH_memory: the client store under a hard byte budget, swept over
//! capacity × eviction policy × trace kind. Writes `BENCH_memory.json`
//! with a `"memory"` section: per cell the MTP, bandwidth demand, peak
//! and mean resident bytes, hit/eviction/overflow counts, and the
//! refetch / notice / staleness accounting, plus a `"hotspot"` section
//! running the multi-client server with every client crowded into the
//! same city quarter.
//!
//!     cargo bench --bench bench_memory [-- --smoke]
//!
//! `--smoke` is the CI canary: a minimal scene and a trimmed sweep, but
//! every parity assertion still executes:
//! * an unbounded budget (client_mem_mb = 0) reproduces the pre-budget
//!   baseline field-for-field with an all-zero `MemCounters` block, for
//!   EVERY policy — the unbounded-parity canary;
//! * a budget tighter than the observed unbounded peak actually evicts
//!   (capacity_evictions + cut_overflow_drops > 0) and its peak stays
//!   at or under the budget — the pressure canary;
//! * the heaviest swept cell is bitwise identical at 1 and 2 threads.
//!
//! Env knobs: `NEBULA_BENCH_SCALE` (scene divisor, default 8),
//! `NEBULA_BENCH_OUT` (output path, default `BENCH_memory.json`).

use nebula::benchkit;
use nebula::coordinator::scheduler::{run_simulation, SimParams};
use nebula::coordinator::{run_multiclient, MemCounters, ServerConfig, Variant};
use nebula::gaussian::BYTES_PER_GAUSSIAN;
use nebula::manage::EvictionPolicy;
use nebula::scene::{dataset, CityGen};
use nebula::trace::TraceKind;
use nebula::util::bench::bench_header;

struct Row {
    mem_mb: f64,
    policy: EvictionPolicy,
    kind: TraceKind,
    mtp_ms: f64,
    bandwidth_bps: f64,
    mem: MemCounters,
}

fn main() {
    bench_header("BENCH_memory", "client store under capacity x policy x trace sweep");
    let smoke = std::env::args().any(|a| a == "--smoke");
    if smoke {
        println!("smoke mode: minimal scene, trimmed capacity sweep");
    }
    let spec = dataset("urban").unwrap();
    let target = (spec.sim_gaussians / benchkit::bench_scale() / if smoke { 4 } else { 1 })
        .max(10_000);
    let tree = CityGen::new(spec.city_params(target)).build();
    let mut params = SimParams::default();
    params.pipeline = benchkit::calibrated_pipeline(&tree, &spec);
    params.pipeline.res_scale = 16;
    params.pipeline.threads = 1;
    let frames = if smoke { 24 } else { 96 };
    println!("scene: {} Gaussians, {frames}-frame traces", tree.len());

    let kinds = [TraceKind::Walk, TraceKind::Teleport];
    let traces: Vec<(TraceKind, Vec<nebula::math::Pose>)> = kinds
        .iter()
        .map(|&k| (k, benchkit::trace_of_kind(&spec, frames, k)))
        .collect();

    // --- Unbounded-parity canary --------------------------------------
    // client_mem_mb = 0 must reproduce the pre-budget behavior
    // field-for-field with MemCounters::default(), whatever the policy.
    let mut peak_unbounded_bytes = 0u64;
    for (kind, poses) in &traces {
        let baseline = run_simulation(&tree, poses, &Variant::nebula(), &params);
        assert_eq!(
            baseline.mem,
            MemCounters::default(),
            "CANARY: unbounded {} run must report all-zero MemCounters",
            kind.label()
        );
        for policy in EvictionPolicy::ALL {
            let mut p = params;
            p.pipeline.client_mem_mb = 0.0;
            p.pipeline.eviction = policy;
            let r = run_simulation(&tree, poses, &Variant::nebula(), &p);
            assert_eq!(
                r, baseline,
                "PARITY VIOLATION: unbounded budget with policy {} diverged on the {} trace",
                policy.label(),
                kind.label()
            );
        }
        peak_unbounded_bytes = peak_unbounded_bytes
            .max(baseline.peak_client_gaussians as u64 * BYTES_PER_GAUSSIAN as u64);
    }
    println!(
        "  parity: unbounded budget == pre-budget baseline for every policy \
         (peak store {} bytes)",
        peak_unbounded_bytes
    );

    // --- Capacity x policy x trace sweep ------------------------------
    // Budgets relative to the observed unbounded peak: 120% (loose),
    // 60% (binding), 30% (starved; full sweep only).
    let fractions: Vec<f64> = if smoke { vec![1.2, 0.6] } else { vec![1.2, 0.6, 0.3] };
    let mut rows: Vec<Row> = Vec::new();
    for (kind, poses) in &traces {
        for &frac in &fractions {
            let mem_mb = peak_unbounded_bytes as f64 * frac / 1e6;
            for policy in EvictionPolicy::ALL {
                let mut p = params;
                p.pipeline.client_mem_mb = mem_mb;
                p.pipeline.eviction = policy;
                let r = run_simulation(&tree, poses, &Variant::nebula(), &p);
                let m = r.mem;
                assert!(
                    m.resident_bytes_peak <= m.capacity_bytes,
                    "CANARY: over-budget frame ({} > {}) at {}x{} {}",
                    m.resident_bytes_peak,
                    m.capacity_bytes,
                    frac,
                    policy.label(),
                    kind.label()
                );
                // Pressure canary: a budget below the unbounded peak
                // must actually evict or shed.
                if frac < 1.0 {
                    assert!(
                        m.capacity_evictions + m.cut_overflow_drops > 0,
                        "CANARY: budget {frac}x never evicted ({} / {})",
                        policy.label(),
                        kind.label()
                    );
                }
                println!(
                    "  {:<8} {:>4.1}x {:<12}: mtp {:>6.2} ms, peak {:>9} B, hits {:>4}, \
                     evict {:>4}, overflow {:>4}, refetch {:>4}, stale {:>4} fr",
                    kind.label(),
                    frac,
                    policy.label(),
                    r.mtp_ms,
                    m.resident_bytes_peak,
                    m.hits,
                    m.capacity_evictions,
                    m.cut_overflow_drops,
                    m.refetch_gaussians,
                    m.stale_member_frames
                );
                rows.push(Row {
                    mem_mb,
                    policy,
                    kind: *kind,
                    mtp_ms: r.mtp_ms,
                    bandwidth_bps: r.bandwidth_bps,
                    mem: m,
                });
            }
        }
    }

    // --- Thread-invariance canary on the heaviest cell ----------------
    // Tightest budget, teleport trace, score policy: the cell with the
    // most eviction/refetch churn must be bitwise thread-invariant.
    let mut heavy = params;
    heavy.pipeline.client_mem_mb = peak_unbounded_bytes as f64 * fractions.last().unwrap() / 1e6;
    heavy.pipeline.eviction = EvictionPolicy::ScoreBased;
    let tele = &traces.last().unwrap().1;
    let t1 = run_simulation(&tree, tele, &Variant::nebula(), &heavy);
    heavy.pipeline.threads = 2;
    let t2 = run_simulation(&tree, tele, &Variant::nebula(), &heavy);
    assert_eq!(
        t1, t2,
        "PARITY VIOLATION: heaviest memory cell diverged between 1 and 2 threads"
    );
    println!("  parity: heaviest cell bitwise identical at 1 and 2 threads");

    // --- Multi-client hotspot cell ------------------------------------
    // Every client walks the same city quarter under a binding budget:
    // overlapping cuts, shared uplink carrying refetch + notice traffic.
    let clients = if smoke { 2 } else { 4 };
    let hs_traces = benchkit::hotspot_traces(&spec, frames, clients);
    let mut mp = params;
    mp.pipeline.client_mem_mb = peak_unbounded_bytes as f64 * 0.6 / 1e6;
    mp.pipeline.eviction = EvictionPolicy::Lru;
    let server = ServerConfig::from_run(&mp.pipeline, &mp.net);
    let hotspot = run_multiclient(&tree, &hs_traces, &Variant::nebula(), &mp, &server);
    assert!(
        hotspot.mem.resident_bytes_peak <= hotspot.mem.capacity_bytes,
        "CANARY: hotspot cell exceeded the per-client budget"
    );
    println!(
        "  hotspot {clients}-client cell: hits {}, evictions {}, refetched {} ({} B), \
         notices {} B, stale {} fr",
        hotspot.mem.hits,
        hotspot.mem.capacity_evictions,
        hotspot.mem.refetch_gaussians,
        hotspot.mem.refetch_bytes,
        hotspot.mem.evict_notice_bytes,
        hotspot.mem.stale_member_frames
    );

    // --- JSON (hand-rolled; serde unavailable offline) -----------------
    let mut j = String::new();
    j.push_str("{\n");
    j.push_str("  \"bench\": \"memory\",\n");
    j.push_str(&format!(
        "  \"scene\": {{\"dataset\": \"{}\", \"target_gaussians\": {target}, \"frames\": {frames}, \"peak_unbounded_bytes\": {peak_unbounded_bytes}}},\n",
        spec.name
    ));
    j.push_str("  \"memory\": [\n");
    for (i, r) in rows.iter().enumerate() {
        j.push_str(&format!(
            "    {{\"trace\": \"{}\", \"mem_mb\": {:.4}, \"policy\": \"{}\", \"mtp_ms\": {:.4}, \"bandwidth_bps\": {:.0}, \"capacity_bytes\": {}, \"resident_bytes_peak\": {}, \"resident_bytes_mean\": {:.1}, \"hits\": {}, \"capacity_evictions\": {}, \"cut_overflow_drops\": {}, \"refetch_rounds\": {}, \"refetch_gaussians\": {}, \"refetch_bytes\": {}, \"evict_notice_bytes\": {}, \"stale_member_frames\": {}}}{}\n",
            r.kind.label(),
            r.mem_mb,
            r.policy.label(),
            r.mtp_ms,
            r.bandwidth_bps,
            r.mem.capacity_bytes,
            r.mem.resident_bytes_peak,
            r.mem.resident_bytes_mean,
            r.mem.hits,
            r.mem.capacity_evictions,
            r.mem.cut_overflow_drops,
            r.mem.refetch_rounds,
            r.mem.refetch_gaussians,
            r.mem.refetch_bytes,
            r.mem.evict_notice_bytes,
            r.mem.stale_member_frames,
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    j.push_str("  ],\n");
    j.push_str(&format!(
        "  \"hotspot\": {{\"clients\": {clients}, \"capacity_bytes\": {}, \"resident_bytes_peak\": {}, \"hits\": {}, \"capacity_evictions\": {}, \"cut_overflow_drops\": {}, \"refetch_gaussians\": {}, \"refetch_bytes\": {}, \"evict_notice_bytes\": {}, \"stale_member_frames\": {}, \"uplink_utilization\": {:.6}}}\n",
        hotspot.mem.capacity_bytes,
        hotspot.mem.resident_bytes_peak,
        hotspot.mem.hits,
        hotspot.mem.capacity_evictions,
        hotspot.mem.cut_overflow_drops,
        hotspot.mem.refetch_gaussians,
        hotspot.mem.refetch_bytes,
        hotspot.mem.evict_notice_bytes,
        hotspot.mem.stale_member_frames,
        hotspot.uplink_utilization
    ));
    j.push_str("}\n");

    let out_path =
        std::env::var("NEBULA_BENCH_OUT").unwrap_or_else(|_| "BENCH_memory.json".to_string());
    std::fs::write(&out_path, &j).expect("write bench json");
    println!("wrote {out_path}");
}
