//! Fig 7: temporal similarity — overlap of the LoD cut between frames
//! separated by growing gaps (paper: 99% at 1 frame, >95% at 64).

use nebula::benchkit::{self, build_scene, walk_trace};
use nebula::lod::{LodSearch, StreamingSearch};
use nebula::scene::dataset;
use nebula::util::bench::bench_header;
use nebula::util::table::{fnum, Table};

fn main() {
    bench_header("Fig 7", "cut overlap vs frame gap (90 FPS walk, HierGS-analogue)");
    let spec = dataset("hiergs").unwrap();
    let tree = build_scene(&spec);
    let pl = benchkit::calibrated_pipeline(&tree, &spec);
    let gaps = [1usize, 2, 4, 8, 16, 32, 64, 128];
    let frames = gaps.iter().max().unwrap() + 1;
    let poses = walk_trace(&spec, frames);
    let mut s = StreamingSearch::default();
    let cuts: Vec<_> =
        poses.iter().map(|p| s.search(&tree, &benchkit::query_at(p, &pl))).collect();

    let mut t = Table::new(vec!["frame gap", "overlap %"]);
    for gap in gaps {
        let o = cuts[0].overlap(&cuts[gap]);
        t.row(vec![gap.to_string(), fnum(o * 100.0, 2)]);
    }
    t.print();
    println!("paper: 99% at gap 1, >95% at gap 64 — the temporal-search premise.");
}
