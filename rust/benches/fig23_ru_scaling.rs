//! Fig 23: scalability — FPS and area vs rendering units per VRC
//! (paper: 256 RUs reach 90 FPS at +62.9% area; plus the §6 area table:
//! GSCore 1.78 mm², Nebula +0.25 mm² ≈ 14%).

use nebula::benchkit::{self, build_scene, walk_trace};
use nebula::hw::energy_area::{area_mm2_16nm, scale_area_to_8nm, SRAM_MM2_PER_KB};
use nebula::hw::{AccelConfig, AccelKind, Accelerator, FrameWorkload, Platform};
use nebula::math::{Intrinsics, StereoCamera};
use nebula::render::raster::RasterConfig;
use nebula::render::stereo::{render_stereo, StereoMode};
use nebula::scene::LARGE_DATASETS;
use nebula::util::bench::bench_header;
use nebula::util::table::{fnum, Table};

fn main() {
    bench_header("Fig 23", "FPS + area vs RUs per VRC");
    // Average stereo workload over the large datasets.
    let mut wl_sum = FrameWorkload::default();
    let mut n = 0u64;
    for spec in LARGE_DATASETS {
        let tree = build_scene(&spec);
        let pl = benchkit::calibrated_pipeline(&tree, &spec);
        let pose = walk_trace(&spec, 8)[7];
        let cam = StereoCamera::new(pose, Intrinsics::vr_eye_scaled(16));
        let cut = benchkit::cut_at(&tree, &pose, &pl);
        let queue = benchkit::queue_for(&tree, &cut);
        let out = render_stereo(
            &cam,
            &benchkit::queue_refs(&queue),
            3,
            pl.tile,
            &RasterConfig::default(),
            StereoMode::AlphaGated,
        );
        let s2 = Intrinsics::vr_eye().pixels() as f64 / cam.intr.pixels() as f64;
        let mut w = FrameWorkload::from_stereo(&out, 2 * Intrinsics::vr_eye().pixels());
        w.alpha_checks = (w.alpha_checks as f64 * s2) as u64;
        w.blends = (w.blends as f64 * s2) as u64;
        w.pairs = (w.pairs as f64 * s2) as u64;
        w.sru_insertions = (w.sru_insertions as f64 * s2) as u64;
        w.merge_ops = (w.merge_ops as f64 * s2) as u64;
        wl_sum.preprocessed += w.preprocessed;
        wl_sum.sorted += w.sorted;
        wl_sum.pairs += w.pairs;
        wl_sum.alpha_checks += w.alpha_checks;
        wl_sum.blends += w.blends;
        wl_sum.sru_insertions += w.sru_insertions;
        wl_sum.merge_ops += w.merge_ops;
        wl_sum.pixels = w.pixels;
        n += 1;
    }
    let wl = FrameWorkload {
        preprocessed: wl_sum.preprocessed / n,
        sorted: wl_sum.sorted / n,
        pairs: wl_sum.pairs / n,
        alpha_checks: wl_sum.alpha_checks / n,
        blends: wl_sum.blends / n,
        sru_insertions: wl_sum.sru_insertions / n,
        merge_ops: wl_sum.merge_ops / n,
        pixels: wl_sum.pixels,
        shared_preproc: true,
        ..Default::default()
    };

    let base_cfg = AccelConfig::default();
    let base_area = area_mm2_16nm(&base_cfg, AccelKind::Nebula);
    let mut t = Table::new(vec!["RUs/VRC", "total RUs", "FPS", "area mm² (16nm)", "area Δ%", "hits 90 FPS?"]);
    for rus in [4u32, 8, 16, 32, 64] {
        let mut cfg = AccelConfig { rus_per_vrc: rus, ..base_cfg };
        // Wider VRCs need proportionally larger buffers (the 62.9% in the
        // paper includes SRAM growth).
        let acc = Accelerator::new(AccelKind::Nebula, cfg);
        let fps = 1.0 / acc.frame_cost(&wl).seconds;
        let extra_buffers =
            (rus as f64 / 16.0 - 1.0).max(0.0) * (16.0 + 18.0) * SRAM_MM2_PER_KB * cfg.vrcs as f64;
        let area = area_mm2_16nm(&cfg, AccelKind::Nebula) + extra_buffers;
        cfg.rus_per_vrc = rus;
        t.row(vec![
            rus.to_string(),
            (rus * cfg.vrcs).to_string(),
            fnum(fps, 1),
            fnum(area, 2),
            fnum((area / base_area - 1.0) * 100.0, 1),
            if fps >= 90.0 { "yes" } else { "no" }.to_string(),
        ]);
    }
    t.print();

    println!("\n§6 area table:");
    let gs = area_mm2_16nm(&base_cfg, AccelKind::GsCore);
    let neb = area_mm2_16nm(&base_cfg, AccelKind::Nebula);
    let mut a = Table::new(vec!["design", "area mm² (16nm)", "area mm² (8nm)", "overhead"]);
    a.row(vec!["GSCore".into(), fnum(gs, 2), fnum(scale_area_to_8nm(gs), 2), "-".to_string()]);
    a.row(vec![
        "Nebula".into(),
        fnum(neb, 2),
        fnum(scale_area_to_8nm(neb), 2),
        format!("+{:.2} mm² ({:.0}%)", neb - gs, (neb / gs - 1.0) * 100.0),
    ]);
    a.print();
    println!("paper: GSCore 1.78 mm²; Nebula +0.25 mm² (~14%); 256 RUs: 90 FPS at +62.9% area.");
}
