//! Fig 18: overall performance — MTP speedup (normalized to GPU) and FPS
//! for GPU / GBU / GSCore / Remote / Nebula, averaged over the large
//! datasets.

use nebula::benchkit::{self, build_scene, walk_trace};
use nebula::coordinator::scheduler::{run_remote_simulation, run_simulation, SimParams};
use nebula::net::VideoQuality;
use nebula::scene::LARGE_DATASETS;
use nebula::util::bench::bench_header;
use nebula::util::table::{fnum, Table};

fn main() {
    bench_header("Fig 18", "overall MTP speedup + FPS (normalized to GPU)");
    let frames = 48;
    let variants = benchkit::fig18_variants();
    let mut sums: Vec<(f64, f64)> = vec![(0.0, 0.0); variants.len()];
    let mut remote_sum = (0.0f64, 0.0f64);
    let mut gpu_mtp_per_scene = Vec::new();

    for spec in LARGE_DATASETS {
        let tree = build_scene(&spec);
        let mut params = SimParams::default();
        params.pipeline = benchkit::calibrated_pipeline(&tree, &spec);
        params.pipeline.res_scale = 16;
        let poses = walk_trace(&spec, frames);
        let mut gpu_mtp = 0.0;
        for (i, v) in variants.iter().enumerate() {
            let r = run_simulation(&tree, &poses, v, &params);
            if i == 0 {
                gpu_mtp = r.mtp_ms;
            }
            sums[i].0 += gpu_mtp / r.mtp_ms;
            sums[i].1 += r.fps;
        }
        let remote = run_remote_simulation(&params, VideoQuality::LossyHigh, frames as u32);
        remote_sum.0 += gpu_mtp / remote.mtp_ms;
        remote_sum.1 += remote.fps;
        gpu_mtp_per_scene.push(gpu_mtp);
    }

    let n = LARGE_DATASETS.len() as f64;
    let mut t = Table::new(vec!["variant", "S: speedup vs GPU", "F: FPS"]);
    for (i, v) in variants.iter().enumerate() {
        t.row(vec![v.name.clone(), fnum(sums[i].0 / n, 2), fnum(sums[i].1 / n, 1)]);
    }
    t.row(vec!["Remote (Lossy-H)".into(), fnum(remote_sum.0 / n, 2), fnum(remote_sum.1 / n, 1)]);
    t.print();
    println!(
        "paper: Nebula 12.1x vs GPU, Remote only 4.6x (network bound); Nebula ~70 FPS \
         at the default 128-RU VRC (90 FPS needs 256 RUs — Fig 23)."
    );
}
