//! BENCH_render: throughput of the parallel tile-scheduled rendering
//! engine on a fixed `scene::citygen` scene, mono + stereo, swept over
//! thread counts. Writes `BENCH_render.json` (ms/frame, pairs/s and
//! speedups vs. the serial reference, plus a per-stage breakdown of the
//! stereo frame — preprocess / sort / binning / left / SRU / right /
//! raster / LoD-validate — with the Amdahl serial fraction implied by
//! each thread count) so the perf trajectory of the hot path is tracked
//! across PRs. Also reports: the quad-lane core vs the scalar reference
//! core (`"raster"`, single-worker core-vs-core), per-frame
//! load-imbalance metrics (`"imbalance"`: max/mean tile-list lengths;
//! per-row steal counts ride the sweep rows), a skewed-list scene
//! comparing round-robin against work-stealing dispatch (`"skewed"`:
//! ms, stealing speedup, per-scheduler Amdahl serial fraction), the
//! pooled-dispatch observability block (`"pool"`: spawn-vs-pool
//! microbenchmark plus queue wait / worker occupancy / submissions from
//! `render::pool`, echoed per stage inside `"stages"`), and the
//! cross-stage pipelining block (`"pipeline"`: whole-trace wall ms at
//! `pipeline.depth` 1 vs 2, overlap ratio, recomputed Amdahl serial
//! fraction for the two-stage overlap).
//!
//!     cargo bench --bench bench_render [-- --smoke]
//!
//! `--smoke` is the CI canary: a minimal scene with one sample per
//! configuration — fast enough for every push, still executing every
//! stage and parity assertion so breakage can't hide behind a skipped
//! bench — and it asserts the quad-lane core is not slower than the
//! scalar reference, and pooled dispatch not slower than the retained
//! scoped-spawn reference, on the smoke scene.
//!
//! Env knobs: `NEBULA_BENCH_SCALE` (scene divisor, default 8),
//! `NEBULA_BENCH_SAMPLES` / `NEBULA_BENCH_WARMUP` (timing loop),
//! `NEBULA_BENCH_OUT` (output path, default `BENCH_render.json`).

use nebula::benchkit;
use nebula::coordinator::{run_simulation, SimParams, Variant};
use nebula::lod::LodSearch;
use nebula::math::{Intrinsics, StereoCamera};
use nebula::render::engine::{
    parallel_map, parallel_map_spawn_reference, parallel_map_stealing,
    parallel_map_stealing_spawn_reference, Parallelism, RowSchedule,
};
use nebula::render::pool;
use nebula::render::raster::{render_bins, render_bins_reference, RasterConfig};
use nebula::render::stereo::{render_stereo, render_stereo_from_splats, StereoMode};
use nebula::render::{preprocess_records, ProjectedSet, TileBins};
use nebula::scene::{CityGen, CityParams};
use nebula::trace::{PoseTrace, TraceParams};
use nebula::util::bench::{bench_header, Bencher};
use nebula::util::Stopwatch;

struct Row {
    mode: &'static str,
    threads: usize, // 0 = serial reference
    ms_per_frame: f64,
    pairs_per_s: f64,
    speedup_vs_serial: f64,
    /// Work-stealing claims off the round-robin placement (mono raster
    /// stage only; diagnostic, placement-dependent).
    steals: u64,
}

fn cfg(par: Parallelism) -> RasterConfig {
    RasterConfig { parallelism: par, ..RasterConfig::default() }
}

fn main() {
    bench_header("BENCH_render", "parallel tile engine, mono + stereo");
    let smoke = std::env::args().any(|a| a == "--smoke");
    if smoke {
        println!("smoke mode: minimal scene, 1 sample/config");
    }
    // Fixed citygen scene; NEBULA_BENCH_SCALE only trims the Gaussian
    // count so CI-class machines finish in seconds.
    let target = (400_000 / benchkit::bench_scale() / if smoke { 4 } else { 1 }).max(10_000);
    let extent = 120.0f32;
    let seed = 20_26u64;
    let tree = CityGen::new(CityParams::for_target(target, extent, seed)).build();
    let pose = PoseTrace::new(TraceParams::default(), extent).generate(4)[3];
    let cam = StereoCamera::new(pose, Intrinsics::vr_eye_scaled(4));
    let (w, h, tile) = (cam.intr.width, cam.intr.height, 16u32);

    // Shared preprocess once; every timed sample re-renders from the
    // same sorted splat set.
    let ids: Vec<u32> = tree.leaves();
    let queue: Vec<_> = ids.iter().map(|&id| (id, tree.gaussians.record(id))).collect();
    let refs = benchkit::queue_refs(&queue);
    let left = cam.left();
    let shared = cam.shared_camera();
    let mut set: ProjectedSet = preprocess_records(&left, &shared, &refs, 3, Parallelism::auto());
    nebula::render::sort::sort_splats_par(&mut set.splats, Parallelism::auto());
    println!(
        "scene: {} Gaussians, {} visible splats, {w}x{h} @ tile {tile}",
        tree.len(),
        set.splats.len()
    );

    // Lighter defaults than Bencher::default() (env still overrides):
    // the sweep times 10 full-frame configurations.
    let env_u32 = |key: &str, default: u32| {
        std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
    };
    let (default_samples, default_warmup) = if smoke { (1, 0) } else { (5, 1) };
    let bencher = Bencher::new(
        env_u32("NEBULA_BENCH_SAMPLES", default_samples),
        env_u32("NEBULA_BENCH_WARMUP", default_warmup),
    );
    let sweep: Vec<(&'static str, Parallelism)> = vec![
        ("serial", Parallelism::Serial),
        ("t1", Parallelism::Threads(1)),
        ("t2", Parallelism::Threads(2)),
        ("t4", Parallelism::Threads(4)),
        ("t8", Parallelism::Threads(8)),
    ];

    let mut rows: Vec<Row> = Vec::new();
    let mut parity: Option<Vec<f32>> = None;

    // --- Mono sweep ----------------------------------------------------
    // Time the rasterization stage the engine parallelizes — bins are
    // prebuilt so the serial sort/bin stages don't dilute the sweep.
    let bins = TileBins::build(w, h, tile, 0, &set.splats);
    let mut mono_serial_ms = 0.0f64;
    for (label, par) in &sweep {
        let c = cfg(*par);
        let (img, stats, steals) = render_bins(&set.splats, &bins, w, h, &c);
        if let Some(reference) = &parity {
            assert_eq!(
                reference, &img.data,
                "PARITY VIOLATION: {label} mono image differs from serial"
            );
        } else {
            parity = Some(img.data.clone());
        }
        let s = bencher.run(|| render_bins(&set.splats, &bins, w, h, &c));
        let ms = s.median_ms();
        let threads = match par {
            Parallelism::Serial => 0,
            Parallelism::Threads(n) => *n,
        };
        if threads == 0 {
            mono_serial_ms = ms;
        }
        rows.push(Row {
            mode: "mono",
            threads,
            ms_per_frame: ms,
            pairs_per_s: stats.pairs as f64 / (ms * 1e-3),
            speedup_vs_serial: if threads == 0 { 1.0 } else { mono_serial_ms / ms },
            steals,
        });
        println!("  mono   {label:>6}: {ms:>8.2} ms/frame  (steals {steals})");
    }

    // --- Quad-lane core vs scalar reference (single worker) ------------
    // Core-vs-core: same bins, same thread count (1), so the delta is
    // purely gather + quad blending vs the indirect scalar loop. The
    // parity assert makes regression impossible to hide; the timing
    // assert is the CI canary (smoke mode) for the perf claim itself.
    let c_serial = cfg(Parallelism::Serial);
    let (quad_img, quad_stats, _) = render_bins(&set.splats, &bins, w, h, &c_serial);
    let (ref_img, ref_stats) = render_bins_reference(&set.splats, &bins, w, h, &c_serial);
    assert_eq!(
        quad_img.data, ref_img.data,
        "PARITY VIOLATION: quad-lane core differs from scalar reference"
    );
    assert_eq!(quad_stats, ref_stats, "PARITY VIOLATION: quad-lane stats differ from scalar");
    let best_of = |k: u32, f: &dyn Fn()| -> f64 {
        let mut best = f64::INFINITY;
        for _ in 0..k {
            let t = Stopwatch::start();
            f();
            best = best.min(t.elapsed_ms());
        }
        best
    };
    // Smoke uses MORE reps, not fewer: the canary asserts on these
    // timings, and best-of-k is the noise shield (one clean run is
    // enough; preemption only ever inflates a sample).
    let reps = if smoke { 7 } else { 5 };
    let quad_ms = best_of(reps, &|| {
        render_bins(&set.splats, &bins, w, h, &c_serial);
    });
    let scalar_ms = best_of(reps, &|| {
        render_bins_reference(&set.splats, &bins, w, h, &c_serial);
    });
    let quad_speedup = scalar_ms / quad_ms;
    println!(
        "  raster core: quad {quad_ms:.2} ms vs scalar {scalar_ms:.2} ms ({quad_speedup:.2}x)"
    );
    if smoke {
        // 25% tolerance on best-of-7: the smoke scene is tiny (few ms,
        // weakest gather amortization), so the margin must absorb CI
        // scheduling noise — a real regression (quad meaningfully
        // slower than scalar) still trips it.
        assert!(
            quad_ms <= scalar_ms * 1.25,
            "CANARY: quad-lane core slower than scalar reference \
             ({quad_ms:.2} ms vs {scalar_ms:.2} ms)"
        );
    }

    // --- Work stealing vs round-robin on a skewed scene ----------------
    // City-scale frames concentrate giant lists in few tile rows
    // (max_list >> mean). Model that: squash 3/4 of the splats into
    // tile row 0 (depth order — the sort key — is untouched) and
    // compare the schedulers at identical thread counts.
    let mut skewed = set.splats.clone();
    for (i, s) in skewed.iter_mut().enumerate() {
        if i % 4 != 0 {
            s.mean.y = (i % tile as usize) as f32 * 0.5 + 0.25; // rows 0..1
        }
    }
    let skew_bins = TileBins::build(w, h, tile, 0, &skewed);
    println!(
        "  skewed scene: max_list {} vs mean {:.1} (base max {} mean {:.1})",
        skew_bins.max_list(),
        skew_bins.mean_list(),
        bins.max_list(),
        bins.mean_list()
    );
    struct SkewRow {
        threads: usize,
        rr_ms: f64,
        steal_ms: f64,
        steal_speedup_vs_rr: f64,
        rr_serial_fraction: f64,
        steal_serial_fraction: f64,
        steals: u64,
    }
    let amdahl = |serial_ms: f64, ms: f64, n: usize| -> f64 {
        if n < 2 || ms <= 0.0 {
            return 1.0;
        }
        let s = serial_ms / ms;
        ((n as f64 / s - 1.0) / (n as f64 - 1.0)).clamp(0.0, 1.0)
    };
    let skew_serial_ms = best_of(reps, &|| {
        render_bins(&skewed, &skew_bins, w, h, &c_serial);
    });
    let mut skew_rows: Vec<SkewRow> = Vec::new();
    for t in [2usize, 4, 8] {
        let rr_cfg =
            RasterConfig { schedule: RowSchedule::RoundRobin, ..cfg(Parallelism::Threads(t)) };
        let st_cfg = cfg(Parallelism::Threads(t)); // stealing by default
        let rr_ms = best_of(reps, &|| {
            render_bins(&skewed, &skew_bins, w, h, &rr_cfg);
        });
        // Steal count rides the timed iterations (Cell: best_of takes
        // &dyn Fn) — no extra probe frame, same as the stereo sweep.
        let steal_cell = std::cell::Cell::new(0u64);
        let steal_ms = best_of(reps, &|| {
            let (_, _, s) = render_bins(&skewed, &skew_bins, w, h, &st_cfg);
            steal_cell.set(s);
        });
        let steals = steal_cell.get();
        let row = SkewRow {
            threads: t,
            rr_ms,
            steal_ms,
            steal_speedup_vs_rr: rr_ms / steal_ms,
            rr_serial_fraction: amdahl(skew_serial_ms, rr_ms, t),
            steal_serial_fraction: amdahl(skew_serial_ms, steal_ms, t),
            steals,
        };
        println!(
            "  skewed t{t}: round-robin {rr_ms:>7.2} ms (frac {:.2})  stealing {steal_ms:>7.2} ms \
             (frac {:.2}, {:.2}x, steals {steals})",
            row.rr_serial_fraction, row.steal_serial_fraction, row.steal_speedup_vs_rr
        );
        skew_rows.push(row);
    }

    // --- Stereo sweep --------------------------------------------------
    // Pair counters are thread-invariant (bitwise parity), so measure
    // them once outside the timing loop.
    let stereo_pairs = {
        let out = render_stereo_from_splats(
            &cam,
            &set,
            tile,
            &cfg(Parallelism::Serial),
            StereoMode::AlphaGated,
        );
        out.stats_left.pairs + out.stats_right.pairs
    };
    let mut stereo_serial_ms = 0.0f64;
    for (label, par) in &sweep {
        let c = cfg(*par);
        // Steal counts ride the timed iterations (last sample wins) —
        // no extra probe frame.
        let mut steals = 0u64;
        let s = bencher.run(|| {
            let out = render_stereo_from_splats(&cam, &set, tile, &c, StereoMode::AlphaGated);
            steals = out.stages.steals_left + out.stages.steals_right;
            out
        });
        let ms = s.median_ms();
        let threads = match par {
            Parallelism::Serial => 0,
            Parallelism::Threads(n) => *n,
        };
        if threads == 0 {
            stereo_serial_ms = ms;
        }
        rows.push(Row {
            mode: "stereo",
            threads,
            ms_per_frame: ms,
            pairs_per_s: stereo_pairs as f64 / (ms * 1e-3),
            speedup_vs_serial: if threads == 0 { 1.0 } else { stereo_serial_ms / ms },
            steals,
        });
        println!("  stereo {label:>6}: {ms:>8.2} ms/frame  (steals {steals})");
    }

    // --- Per-stage breakdown
    // (preprocess / sort / binning / left / SRU / right / validate).
    // Every stage of the stereo frame now rides the engine — sort and
    // binning are timed separately (they were folded into
    // preprocess/left before this PR, hiding the last serial pieces) —
    // so the Amdahl serial fraction implied by the whole-frame speedup
    // (s = (n/S - 1)/(n - 1)) attributes correctly and is tracked
    // shrinking across PRs.
    struct StageRow {
        threads: usize,
        pre_ms: f64,
        sort_ms: f64,
        bin_ms: f64,
        left_ms: f64,
        sru_ms: f64,
        right_ms: f64,
        validate_ms: f64,
        frame_ms: f64,
        amdahl_serial_fraction: f64,
        /// Raster stage total (left + right blend phases).
        raster_ms: f64,
        steals_left: u64,
        steals_right: u64,
        /// Pool dispatch telemetry per engine phase (queue wait,
        /// occupancy, submissions) — all-zero on the serial rows.
        pool_left: pool::DispatchStats,
        pool_sru: pool::DispatchStats,
        pool_right: pool::DispatchStats,
    }
    let median = |xs: &mut Vec<f64>| -> f64 {
        xs.sort_by(f64::total_cmp);
        xs[xs.len() / 2]
    };
    // A real LoD cut for the validate-stage timing.
    let query = nebula::lod::LodQuery::new(pose.position, cam.intr.fx, 6.0, cam.intr.near);
    let lod_cut = nebula::lod::StreamingSearch::default().search(&tree, &query);
    let n_samples = env_u32("NEBULA_BENCH_SAMPLES", default_samples).max(1) as usize;
    let n_warmup = env_u32("NEBULA_BENCH_WARMUP", default_warmup) as usize;
    let mut stage_rows: Vec<StageRow> = Vec::new();
    let mut stage_serial_frame = 0.0f64;
    for (label, par) in &sweep {
        let c = cfg(*par);
        let (mut pre, mut srt, mut bin, mut lft, mut sru, mut rgt, mut val) = (
            Vec::new(),
            Vec::new(),
            Vec::new(),
            Vec::new(),
            Vec::new(),
            Vec::new(),
            Vec::new(),
        );
        let (mut steals_left, mut steals_right) = (0u64, 0u64);
        let (mut pool_left, mut pool_sru, mut pool_right) = (
            pool::DispatchStats::default(),
            pool::DispatchStats::default(),
            pool::DispatchStats::default(),
        );
        for i in 0..n_samples + n_warmup {
            let out = render_stereo(&cam, &refs, 3, tile, &c, StereoMode::AlphaGated);
            let t = Stopwatch::start();
            lod_cut.validate_par(&tree, &query, *par).expect("cut is valid");
            if i < n_warmup {
                continue; // warmup
            }
            val.push(t.elapsed_ms());
            pre.push(out.stages.preprocess * 1e3);
            srt.push(out.stages.sort * 1e3);
            bin.push(out.stages.binning * 1e3);
            lft.push(out.stages.left * 1e3);
            sru.push(out.stages.sru * 1e3);
            rgt.push(out.stages.right * 1e3);
            steals_left = out.stages.steals_left;
            steals_right = out.stages.steals_right;
            pool_left = out.stages.pool_left;
            pool_sru = out.stages.pool_sru;
            pool_right = out.stages.pool_right;
        }
        let (pre_ms, sort_ms, bin_ms, left_ms, sru_ms, right_ms, validate_ms) = (
            median(&mut pre),
            median(&mut srt),
            median(&mut bin),
            median(&mut lft),
            median(&mut sru),
            median(&mut rgt),
            median(&mut val),
        );
        let frame_ms = pre_ms + sort_ms + bin_ms + left_ms + sru_ms + right_ms;
        let threads = match par {
            Parallelism::Serial => 0,
            Parallelism::Threads(n) => *n,
        };
        if threads == 0 {
            stage_serial_frame = frame_ms;
        }
        let amdahl_serial_fraction = if threads >= 2 && frame_ms > 0.0 {
            let s = stage_serial_frame / frame_ms; // whole-frame speedup
            let n = threads as f64;
            ((n / s - 1.0) / (n - 1.0)).clamp(0.0, 1.0)
        } else {
            1.0 // one worker: the whole frame is serial by definition
        };
        println!(
            "  stages {label:>6}: pre {pre_ms:>7.2}  sort {sort_ms:>6.2}  bin {bin_ms:>6.2}  \
             left {left_ms:>7.2}  sru {sru_ms:>6.2}  right {right_ms:>7.2}  \
             validate {validate_ms:>6.3} ms  (serial frac {amdahl_serial_fraction:.2}, \
             steals {steals_left}+{steals_right})"
        );
        stage_rows.push(StageRow {
            threads,
            pre_ms,
            sort_ms,
            bin_ms,
            left_ms,
            sru_ms,
            right_ms,
            validate_ms,
            frame_ms,
            amdahl_serial_fraction,
            raster_ms: left_ms + right_ms,
            steals_left,
            steals_right,
            pool_left,
            pool_sru,
            pool_right,
        });
    }

    // --- Pooled dispatch vs scoped-spawn reference ----------------------
    // Same items, same worker, same thread count: the delta is pure
    // dispatch overhead (ticket open/close + worker span reporting vs
    // the retained pre-pool scoped-spawn bodies). Parity is asserted
    // first, so the timing claim can never drift from the correctness
    // claim.
    let disp_items: Vec<u64> = (0..4096u64).collect();
    let disp_costs: Vec<u64> = disp_items.iter().map(|&i| 1 + i % 31).collect();
    let disp_work = |_: usize, v: u64| {
        let mut acc = v;
        for round in 0..64u64 {
            acc = acc.wrapping_mul(0x9E3779B97F4A7C15).rotate_left(13) ^ round;
        }
        acc
    };
    let disp_par = Parallelism::Threads(4);
    assert_eq!(
        parallel_map(disp_items.clone(), disp_par, disp_work),
        parallel_map_spawn_reference(disp_items.clone(), disp_par, disp_work),
        "PARITY VIOLATION: pooled map differs from spawn reference"
    );
    assert_eq!(
        parallel_map_stealing(disp_items.clone(), &disp_costs, disp_par, disp_work).0,
        parallel_map_stealing_spawn_reference(disp_items.clone(), &disp_costs, disp_par, disp_work)
            .0,
        "PARITY VIOLATION: pooled stealing differs from spawn reference"
    );
    let pool_map_ms = best_of(reps, &|| {
        parallel_map(disp_items.clone(), disp_par, disp_work);
    });
    // Harvest the telemetry of the last pooled dispatch this thread ran.
    let disp_stats = pool::last_dispatch();
    let spawn_map_ms = best_of(reps, &|| {
        parallel_map_spawn_reference(disp_items.clone(), disp_par, disp_work);
    });
    let pool_steal_ms = best_of(reps, &|| {
        parallel_map_stealing(disp_items.clone(), &disp_costs, disp_par, disp_work);
    });
    let spawn_steal_ms = best_of(reps, &|| {
        parallel_map_stealing_spawn_reference(disp_items.clone(), &disp_costs, disp_par, disp_work);
    });
    println!(
        "  dispatch t4: pooled map {pool_map_ms:.3} ms vs spawn {spawn_map_ms:.3} ms; \
         stealing {pool_steal_ms:.3} ms vs spawn {spawn_steal_ms:.3} ms \
         (occupancy {:.2}, queue wait {:.3} ms, {} submissions)",
        disp_stats.occupancy,
        disp_stats.queue_wait_s * 1e3,
        disp_stats.submissions
    );
    if smoke {
        // Same 25% best-of-7 margin as the quad canary: pooled dispatch
        // must not cost measurably more than the spawn bodies it
        // replaced.
        assert!(
            pool_map_ms <= spawn_map_ms * 1.25,
            "CANARY: pooled dispatch slower than scoped spawn \
             ({pool_map_ms:.3} ms vs {spawn_map_ms:.3} ms)"
        );
    }

    // --- Cross-stage frame pipelining (depth 1 vs 2) --------------------
    // Whole-trace wall clock through the real scheduler: depth 2
    // overlaps each LoD round with its own frame's render on a second
    // thread. Outputs are pinned field-for-field by `tests/
    // it_pipeline.rs`; the cheap whole-struct check here keeps the
    // timing claim honest, so the delta is pure overlap.
    let pipe_frames = if smoke { 6 } else { 12 };
    let pipe_poses = PoseTrace::new(TraceParams::default(), extent).generate(pipe_frames);
    let pipe_params = |depth: u32| {
        let mut p = SimParams::default();
        p.pipeline.res_scale = 16;
        p.pipeline.threads = 2;
        p.pipeline.depth = depth;
        p
    };
    let seq_out = run_simulation(&tree, &pipe_poses, &Variant::nebula(), &pipe_params(1));
    let pipe_out = run_simulation(&tree, &pipe_poses, &Variant::nebula(), &pipe_params(2));
    assert_eq!(seq_out, pipe_out, "PARITY VIOLATION: pipelined run differs from sequential");
    let depth1_ms = best_of(reps, &|| {
        run_simulation(&tree, &pipe_poses, &Variant::nebula(), &pipe_params(1));
    });
    let depth2_ms = best_of(reps, &|| {
        run_simulation(&tree, &pipe_poses, &Variant::nebula(), &pipe_params(2));
    });
    let overlap_ratio = if depth2_ms > 0.0 { depth1_ms / depth2_ms } else { 1.0 };
    let pipe_serial_fraction = amdahl(depth1_ms, depth2_ms, 2);
    println!(
        "  pipeline ({pipe_frames} frames, 2 threads): depth1 {depth1_ms:.2} ms, \
         depth2 {depth2_ms:.2} ms ({overlap_ratio:.2}x, serial frac {pipe_serial_fraction:.2})"
    );

    let speedup_of = |mode: &str, threads: usize| {
        rows.iter()
            .find(|r| r.mode == mode && r.threads == threads)
            .map(|r| r.speedup_vs_serial)
            .unwrap_or(0.0)
    };
    let mono4 = speedup_of("mono", 4);
    let stereo4 = speedup_of("stereo", 4);
    println!("speedup @4 threads: mono {mono4:.2}x, stereo {stereo4:.2}x");

    // --- JSON (hand-rolled; serde unavailable offline) -----------------
    let mut j = String::new();
    j.push_str("{\n");
    j.push_str("  \"bench\": \"render\",\n");
    j.push_str(&format!(
        "  \"scene\": {{\"generator\": \"citygen\", \"target_gaussians\": {target}, \"extent_m\": {extent:.1}, \"seed\": {seed}, \"splats\": {}}},\n",
        set.splats.len()
    ));
    j.push_str(&format!(
        "  \"image\": {{\"width\": {w}, \"height\": {h}, \"tile\": {tile}}},\n"
    ));
    j.push_str(&format!("  \"speedup_mono_4t\": {mono4:.3},\n"));
    j.push_str(&format!("  \"speedup_stereo_4t\": {stereo4:.3},\n"));
    j.push_str(&format!(
        "  \"raster\": {{\"quad_ms\": {quad_ms:.3}, \"scalar_ms\": {scalar_ms:.3}, \"quad_vs_scalar_speedup\": {quad_speedup:.3}}},\n"
    ));
    j.push_str(&format!(
        "  \"imbalance\": {{\"max_list\": {}, \"mean_list\": {:.2}, \"skewed_max_list\": {}, \"skewed_mean_list\": {:.2}}},\n",
        bins.max_list(),
        bins.mean_list(),
        skew_bins.max_list(),
        skew_bins.mean_list()
    ));
    j.push_str("  \"skewed\": [\n");
    for (i, r) in skew_rows.iter().enumerate() {
        j.push_str(&format!(
            "    {{\"threads\": {}, \"round_robin_ms\": {:.3}, \"stealing_ms\": {:.3}, \"stealing_speedup_vs_rr\": {:.3}, \"rr_serial_fraction\": {:.4}, \"stealing_serial_fraction\": {:.4}, \"steals\": {}}}{}\n",
            r.threads,
            r.rr_ms,
            r.steal_ms,
            r.steal_speedup_vs_rr,
            r.rr_serial_fraction,
            r.steal_serial_fraction,
            r.steals,
            if i + 1 == skew_rows.len() { "" } else { "," }
        ));
    }
    j.push_str("  ],\n");
    j.push_str(&format!(
        "  \"pool\": {{\"threads\": 4, \"items\": {}, \"map_pool_ms\": {pool_map_ms:.4}, \"map_spawn_ms\": {spawn_map_ms:.4}, \"stealing_pool_ms\": {pool_steal_ms:.4}, \"stealing_spawn_ms\": {spawn_steal_ms:.4}, \"queue_wait_ms\": {:.4}, \"occupancy\": {:.4}, \"submissions\": {}}},\n",
        disp_items.len(),
        disp_stats.queue_wait_s * 1e3,
        disp_stats.occupancy,
        disp_stats.submissions
    ));
    j.push_str(&format!(
        "  \"pipeline\": {{\"threads\": 2, \"frames\": {pipe_frames}, \"depth1_wall_ms\": {depth1_ms:.3}, \"depth2_wall_ms\": {depth2_ms:.3}, \"overlap_ratio\": {overlap_ratio:.3}, \"serial_fraction\": {pipe_serial_fraction:.4}}},\n"
    ));
    j.push_str("  \"results\": [\n");
    for (i, r) in rows.iter().enumerate() {
        j.push_str(&format!(
            "    {{\"mode\": \"{}\", \"threads\": {}, \"ms_per_frame\": {:.3}, \"pairs_per_s\": {:.0}, \"speedup_vs_serial\": {:.3}, \"steals\": {}}}{}\n",
            r.mode,
            r.threads,
            r.ms_per_frame,
            r.pairs_per_s,
            r.speedup_vs_serial,
            r.steals,
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    j.push_str("  ],\n");
    j.push_str("  \"stages\": [\n");
    let pool_json = |s: &pool::DispatchStats| {
        format!(
            "{{\"queue_wait_ms\": {:.4}, \"occupancy\": {:.4}, \"submissions\": {}}}",
            s.queue_wait_s * 1e3,
            s.occupancy,
            s.submissions
        )
    };
    for (i, r) in stage_rows.iter().enumerate() {
        j.push_str(&format!(
            "    {{\"threads\": {}, \"preprocess_ms\": {:.3}, \"sort_ms\": {:.3}, \"binning_ms\": {:.3}, \"left_ms\": {:.3}, \"sru_ms\": {:.3}, \"right_ms\": {:.3}, \"raster_ms\": {:.3}, \"validate_ms\": {:.4}, \"frame_ms\": {:.3}, \"amdahl_serial_fraction\": {:.4}, \"steals_left\": {}, \"steals_right\": {}, \"pool_left\": {}, \"pool_sru\": {}, \"pool_right\": {}}}{}\n",
            r.threads,
            r.pre_ms,
            r.sort_ms,
            r.bin_ms,
            r.left_ms,
            r.sru_ms,
            r.right_ms,
            r.raster_ms,
            r.validate_ms,
            r.frame_ms,
            r.amdahl_serial_fraction,
            r.steals_left,
            r.steals_right,
            pool_json(&r.pool_left),
            pool_json(&r.pool_sru),
            pool_json(&r.pool_right),
            if i + 1 == stage_rows.len() { "" } else { "," }
        ));
    }
    j.push_str("  ]\n}\n");

    let out_path =
        std::env::var("NEBULA_BENCH_OUT").unwrap_or_else(|_| "BENCH_render.json".to_string());
    std::fs::write(&out_path, &j).expect("write bench json");
    println!("wrote {out_path}");
}
