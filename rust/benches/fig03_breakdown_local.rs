//! Fig 3: end-to-end stage breakdown of LOCAL rendering on the mobile
//! GPU across scene scales — LoD search grows to ~47% of the frame on
//! large scenes while rasterization's share plateaus.

use nebula::benchkit::{self, build_scene, walk_trace};
use nebula::hw::{FrameWorkload, MobileGpu, Platform};
use nebula::lod::{LodSearch, StreamingSearch};
use nebula::math::{Intrinsics, StereoCamera};
use nebula::render::raster::RasterConfig;
use nebula::render::stereo::{render_stereo, StereoMode};
use nebula::scene::ALL_DATASETS;
use nebula::util::bench::bench_header;
use nebula::util::table::{fnum, Table};

fn main() {
    bench_header("Fig 3", "local rendering breakdown on mobile GPU");
    let mut t = Table::new(vec![
        "dataset", "lod %", "preprocess %", "sort %", "raster %", "frame ms",
    ]);
    let full = Intrinsics::vr_eye();
    for spec in ALL_DATASETS {
        let tree = build_scene(&spec);
        let pl = benchkit::calibrated_pipeline(&tree, &spec);
        let pose = walk_trace(&spec, 16)[15];
        // Local rendering = the client runs LoD search itself each frame.
        let cut = StreamingSearch::default().search(&tree, &benchkit::query_at(&pose, &pl));
        let queue = benchkit::queue_for(&tree, &cut.nodes);
        let cam = StereoCamera::new(pose, Intrinsics::vr_eye_scaled(16));
        let out = render_stereo(
            &cam,
            &benchkit::queue_refs(&queue),
            pl.sh_degree,
            pl.tile,
            &RasterConfig::default(),
            StereoMode::AlphaGated,
        );
        let s2 = full.pixels() as f64 / cam.intr.pixels() as f64;
        let mut wl = FrameWorkload::from_stereo(&out, 2 * full.pixels());
        wl.alpha_checks = (wl.alpha_checks as f64 * s2) as u64;
        wl.blends = (wl.blends as f64 * s2) as u64;
        wl.pairs = (wl.pairs as f64 * s2) as u64;
        // Local LoD search on-device: visits scale with the full tree at
        // the paper's scale — extrapolate via the registry ratio.
        let scale_up = spec.sim_gaussians as f64 / tree.len() as f64;
        wl = wl.with_lod_visits((cut.nodes_visited as f64 * scale_up) as u64);

        let cost = MobileGpu::orin().frame_cost(&wl);
        let total: f64 = cost.stages.iter().map(|(_, s)| s).sum();
        let pct = |name: &str| {
            100.0 * cost.stages.iter().find(|(n, _)| *n == name).unwrap().1 / total
        };
        t.row(vec![
            spec.name.to_string(),
            fnum(pct("lod+decode"), 1),
            fnum(pct("preprocess"), 1),
            fnum(pct("sort"), 1),
            fnum(pct("raster"), 1),
            fnum(total * 1e3, 1),
        ]);
    }
    t.print();
    println!("paper: LoD-search share grows with scene scale, up to ~47%.");
}
