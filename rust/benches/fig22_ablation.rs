//! Fig 22: ablation — BASE (+collab on the Nebula architecture) adding
//! CMP (compression), TA (temporal-aware search), SR (stereo
//! rasterization). Paper: +CMP 2.5x, +CMP+TA 2.7x, all 3.9x speedup;
//! energy savings 1.5x → 2.0x.

use nebula::benchkit::{self, build_scene, walk_trace};
use nebula::compress::CompressionMode;
use nebula::coordinator::metrics::{PlatformKind, Variant};
use nebula::coordinator::scheduler::{run_simulation, SimParams};
use nebula::scene::LARGE_DATASETS;
use nebula::util::bench::bench_header;
use nebula::util::table::{fnum, Table};

fn main() {
    bench_header("Fig 22", "ablation: BASE / +CMP / +CMP+TA / +CMP+TA+SR (Nebula)");
    let variants = [
        ("BASE", CompressionMode::Raw, false, false),
        ("BASE+CMP", CompressionMode::Quantized, false, false),
        ("BASE+CMP+TA", CompressionMode::Quantized, true, false),
        ("Nebula (all)", CompressionMode::Quantized, true, true),
    ];
    let mut sums = vec![(0.0f64, 0.0f64, 0.0f64); variants.len()]; // speedup, energy, bytes
    let mut n = 0.0;

    for spec in LARGE_DATASETS {
        let tree = build_scene(&spec);
        let mut params = SimParams::default();
        params.pipeline = benchkit::calibrated_pipeline(&tree, &spec);
        params.pipeline.res_scale = 16;
        let poses = walk_trace(&spec, 48);
        let mut base = None;
        for (i, (name, cmp, ta, sr)) in variants.iter().enumerate() {
            let v = Variant {
                name: name.to_string(),
                platform: PlatformKind::NebulaArch,
                stereo: *sr,
                compression: *cmp,
                temporal: *ta,
            };
            let r = run_simulation(&tree, &poses, &v, &params);
            let b = base.get_or_insert((r.mtp_ms, r.client_energy_j));
            sums[i].0 += b.0 / r.mtp_ms;
            sums[i].1 += b.1 / r.client_energy_j;
            sums[i].2 += r.initial_bytes as f64;
        }
        n += 1.0;
    }

    let mut t = Table::new(vec!["variant", "speedup", "energy saving", "initial load MB"]);
    for (i, (name, ..)) in variants.iter().enumerate() {
        t.row(vec![
            name.to_string(),
            fnum(sums[i].0 / n, 2),
            fnum(sums[i].1 / n, 2),
            fnum(sums[i].2 / n / 1e6, 2),
        ]);
    }
    t.print();
    println!("paper: 2.5x / 2.7x / 3.9x speedup; 1.5x / 1.5x / 2.0x energy savings.");
}
