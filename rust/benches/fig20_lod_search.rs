//! Fig 20: LoD-search speedup over the OctreeGS-style flat scan:
//! CityGS-like chunked scan, HierGS-like traversal, Nebula streaming and
//! temporal-aware search (paper: up to 52.7x).

use nebula::benchkit::{self, build_scene, walk_trace};
use nebula::lod::{ChunkedSearch, FlatScanSearch, FullSearch, LodSearch, StreamingSearch, TemporalSearch};
use nebula::scene::LARGE_DATASETS;
use nebula::util::bench::{bench_header, Bencher};
use nebula::util::table::{fnum, Table};

fn main() {
    bench_header("Fig 20", "LoD search speedup (baseline: OctreeGS flat scan)");
    let mut t = Table::new(vec![
        "dataset", "algorithm", "ms/frame", "visits/frame", "speedup (time)", "speedup (visits)",
    ]);
    let b = Bencher::new(5, 1);
    for spec in LARGE_DATASETS {
        let tree = build_scene(&spec);
        let pl = benchkit::calibrated_pipeline(&tree, &spec);
        let poses = walk_trace(&spec, 16);
        let queries: Vec<_> = poses.iter().map(|p| benchkit::query_at(p, &pl)).collect();

        let run = |_name: &str, search: &mut dyn LodSearch| -> (f64, f64) {
            // Warm temporal state outside the timed region.
            search.search(&tree, &queries[0]);
            let sample = b.run(|| {
                let mut visits = 0u64;
                for q in &queries[1..] {
                    visits += search.search(&tree, q).nodes_visited;
                }
                visits
            });
            let mut visits = 0u64;
            for q in &queries[1..] {
                visits += search.search(&tree, q).nodes_visited;
            }
            let per_frame_ms = sample.median_ms() / (queries.len() - 1) as f64;
            let per_frame_visits = visits as f64 / (queries.len() - 1) as f64;
            (per_frame_ms, per_frame_visits)
        };

        let base = run("_flat", &mut FlatScanSearch);
        let rows = [
            ("OctreeGS (flat scan)", base),
            ("CityGS (chunked)", run("chunked", &mut ChunkedSearch::default())),
            ("HierGS (tree traversal)", run("full", &mut FullSearch::new())),
            ("Nebula streaming", run("streaming", &mut StreamingSearch::default())),
            ("Nebula temporal-aware", run("temporal", &mut TemporalSearch::for_tree(&tree))),
        ];
        for (name, (ms, visits)) in rows {
            t.row(vec![
                spec.name.to_string(),
                name.to_string(),
                fnum(ms, 3),
                fnum(visits, 0),
                fnum(base.0 / ms, 1),
                fnum(base.1 / visits.max(1.0), 1),
            ]);
        }
    }
    t.print();
    println!("paper: temporal-aware search reaches up to 52.7x over the OctreeGS baseline.");
}
