//! Fig 8: stereo similarity — fraction of pixels shared between the
//! left- and right-eye images (paper: <1% non-overlapping), measured by
//! disparity-warping left→right coverage.

use nebula::benchkit::{self, build_scene, walk_trace};
use nebula::math::{Intrinsics, StereoCamera};
use nebula::render::raster::{render_bins, RasterConfig};
use nebula::render::warp::depth_map;
use nebula::render::{preprocess_records, Parallelism, TileBins};
use nebula::scene::ALL_DATASETS;
use nebula::util::bench::bench_header;
use nebula::util::table::{fnum, Table};

fn main() {
    bench_header("Fig 8", "left/right eye pixel overlap");
    let mut t = Table::new(vec!["dataset", "overlapping %", "disoccluded %"]);
    for spec in ALL_DATASETS {
        let tree = build_scene(&spec);
        let pl = benchkit::calibrated_pipeline(&tree, &spec);
        let pose = walk_trace(&spec, 12)[11];
        let cam = StereoCamera::new(pose, Intrinsics::vr_eye_scaled(16));
        let cut = benchkit::cut_at(&tree, &pose, &pl);
        let queue = benchkit::queue_for(&tree, &cut);
        let left = cam.left();
        let mut set = preprocess_records(&left, &cam.shared_camera(), &benchkit::queue_refs(&queue), 3, Parallelism::auto());
        nebula::render::sort::sort_splats_par(&mut set.splats, Parallelism::auto());
        let cfg = RasterConfig::default();
        let bins = TileBins::build_par(
            cam.intr.width,
            cam.intr.height,
            pl.tile,
            0,
            &set.splats,
            Parallelism::auto(),
        );
        let (_, _, _) = render_bins(&set.splats, &bins, cam.intr.width, cam.intr.height, &cfg);
        let depth =
            depth_map(&set.splats, &bins, cam.intr.width, cam.intr.height, &cfg, cam.intr.far);

        // Forward-warp coverage: right pixels hit by some left pixel.
        let (w, h) = (cam.intr.width, cam.intr.height);
        let mut covered = vec![false; (w * h) as usize];
        for y in 0..h {
            for x in 0..w {
                let d = depth[(y * w + x) as usize];
                let disp = cam.disparity_px(d);
                let xr = (x as f32 - disp).round();
                if xr >= 0.0 && xr < w as f32 {
                    covered[(y * w + xr as u32) as usize] = true;
                }
            }
        }
        let cov = covered.iter().filter(|&&c| c).count() as f64 / covered.len() as f64;
        t.row(vec![
            spec.name.to_string(),
            fnum(cov * 100.0, 2),
            fnum((1.0 - cov) * 100.0, 2),
        ]);
    }
    t.print();
    println!("paper: <1% of pixels are non-overlapping between the eyes.");
}
