//! Fig 21: client-side speedup of stereo rasterization (preprocess +
//! sort + raster) over rendering both eyes, on each hardware platform
//! (paper: 1.4x GPU, 1.9x GBU, 1.7x GSCore).

use nebula::benchkit::{self, build_scene, walk_trace};
use nebula::hw::{AccelConfig, AccelKind, Accelerator, FrameWorkload, MobileGpu, Platform};
use nebula::math::{Intrinsics, StereoCamera};
use nebula::render::raster::{render_mono, RasterConfig};
use nebula::render::stereo::{render_stereo, StereoMode};
use nebula::render::{preprocess_records, Parallelism};
use nebula::scene::ALL_DATASETS;
use nebula::util::bench::bench_header;
use nebula::util::table::{fnum, Table};

fn main() {
    bench_header("Fig 21", "stereo-raster speedup per platform (Base = both eyes)");
    let platforms: Vec<(&str, Box<dyn Platform>)> = vec![
        ("GPU", Box::new(MobileGpu::orin())),
        ("GBU", Box::new(Accelerator::new(AccelKind::Gbu, AccelConfig::default()))),
        ("GSCore", Box::new(Accelerator::new(AccelKind::GsCore, AccelConfig::default()))),
        ("Nebula-arch", Box::new(Accelerator::new(AccelKind::Nebula, AccelConfig::default()))),
    ];
    let mut sums = vec![0.0f64; platforms.len()];
    let mut n = 0.0;

    for spec in ALL_DATASETS {
        let tree = build_scene(&spec);
        let pl = benchkit::calibrated_pipeline(&tree, &spec);
        let pose = walk_trace(&spec, 16)[15];
        let cam = StereoCamera::new(pose, Intrinsics::vr_eye_scaled(16));
        let cut = benchkit::cut_at(&tree, &pose, &pl);
        let queue = benchkit::queue_for(&tree, &cut);
        let refs = benchkit::queue_refs(&queue);
        let cfg = RasterConfig::default();
        let pixels = 2 * Intrinsics::vr_eye().pixels();

        // Base workload: both eyes independently.
        let lset = preprocess_records(&cam.left(), &cam.left(), &refs, 3, Parallelism::auto());
        let rset = preprocess_records(&cam.right(), &cam.right(), &refs, 3, Parallelism::auto());
        let count = (lset.splats.len() + rset.splats.len()) / 2;
        let (_, ls, _) = render_mono(lset, cam.intr.width, cam.intr.height, pl.tile, &cfg);
        let (_, rs, _) = render_mono(rset, cam.intr.width, cam.intr.height, pl.tile, &cfg);
        let base_wl = FrameWorkload::from_mono_pair(count, &ls, &rs, pixels);

        // Stereo workload: shared preprocess + SRU/merge lists.
        let out = render_stereo(&cam, &refs, 3, pl.tile, &cfg, StereoMode::AlphaGated);
        let stereo_wl = FrameWorkload::from_stereo(&out, pixels);

        for (i, (_, p)) in platforms.iter().enumerate() {
            let base = p.frame_cost(&base_wl).seconds;
            let stereo = p.frame_cost(&stereo_wl).seconds;
            sums[i] += base / stereo;
        }
        n += 1.0;
    }

    let mut t = Table::new(vec!["platform", "stereo-raster speedup", "paper"]);
    let paper = ["1.4x", "1.9x", "1.7x", "-"];
    for (i, (name, _)) in platforms.iter().enumerate() {
        t.row(vec![name.to_string(), fnum(sums[i] / n, 2), paper[i].to_string()]);
    }
    t.print();
}
