//! BENCH_faults: the streaming path swept over link loss rate ×
//! scheduled outage length. Writes `BENCH_faults.json` with a
//! `"faults"` section: per cell the mean/p99 MTP, bandwidth demand,
//! lost/retransmitted/abandoned message counts, keyframe resyncs, and
//! the staleness distribution (mean / p99 / worst recovery span), plus
//! a `"degraded"` section exercising the multi-client admission-control
//! and quality-degradation knobs under a mid-run disconnect.
//!
//!     cargo bench --bench bench_faults [-- --smoke]
//!
//! `--smoke` is the CI canary: a minimal scene and a 2×2 sweep, but
//! every parity assertion still executes:
//! * a zero-probability `FaultPlan` (all fault knobs zero, nonzero
//!   seed) reproduces the faultless baseline field-for-field, with
//!   all-zero fault counters — the faults-off ≡ pre-fault-API canary;
//! * the heaviest sweep cell is bitwise identical at 1 and 2 threads;
//! * every swept cell reports finite p99 MTP and finite staleness
//!   percentiles (clients recover within the retry/resync budget).
//!
//! Env knobs: `NEBULA_BENCH_SCALE` (scene divisor, default 8),
//! `NEBULA_BENCH_OUT` (output path, default `BENCH_faults.json`).

use nebula::benchkit;
use nebula::coordinator::scheduler::{run_simulation, SimParams};
use nebula::coordinator::{run_multiclient, Disconnect, FaultCounters, ServerConfig, Variant};
use nebula::scene::{dataset, CityGen};
use nebula::util::bench::bench_header;

struct Row {
    loss_prob: f64,
    outage_len_s: f64,
    mtp_ms: f64,
    mtp_p99_ms: f64,
    bandwidth_bps: f64,
    faults: FaultCounters,
}

fn main() {
    bench_header("BENCH_faults", "streaming path under loss x outage sweep");
    let smoke = std::env::args().any(|a| a == "--smoke");
    if smoke {
        println!("smoke mode: minimal scene, 2x2 loss x outage sweep");
    }
    let spec = dataset("urban").unwrap();
    let target = (spec.sim_gaussians / benchkit::bench_scale() / if smoke { 4 } else { 1 })
        .max(10_000);
    let tree = CityGen::new(spec.city_params(target)).build();
    let mut params = SimParams::default();
    params.pipeline = benchkit::calibrated_pipeline(&tree, &spec);
    params.pipeline.res_scale = 16;
    params.pipeline.threads = 1;
    let frames = if smoke { 24 } else { 96 };
    let poses = benchkit::walk_trace(&spec, frames);
    println!("scene: {} Gaussians, {frames}-frame trace", tree.len());

    // --- Parity canary: zero-fault plan == faultless baseline ---------
    // `params.net` is the pristine default (every fault knob zero); the
    // second run sets a nonzero seed but leaves all probabilities and
    // windows zero, so the plan must stay inactive and the results must
    // match FIELD-FOR-FIELD with all-zero fault counters.
    let baseline = run_simulation(&tree, &poses, &Variant::nebula(), &params);
    let mut zeroed = params;
    zeroed.net.fault_seed = 0xDEAD_BEEF;
    zeroed.net.retry_limit = 7; // retry budget is inert on a clean link
    let zero_fault = run_simulation(&tree, &poses, &Variant::nebula(), &zeroed);
    assert_eq!(
        zero_fault, baseline,
        "PARITY VIOLATION: zero-probability FaultPlan diverged from the faultless baseline"
    );
    assert_eq!(
        baseline.faults,
        FaultCounters::default(),
        "CANARY: faultless run must report all-zero fault counters"
    );
    println!("  parity: zero-fault plan == faultless baseline (field-for-field)");

    // --- Loss x outage sweep ------------------------------------------
    let loss_sweep: Vec<f64> = if smoke { vec![0.0, 0.05] } else { vec![0.0, 0.01, 0.05, 0.15] };
    let outage_sweep: Vec<f64> = if smoke { vec![0.0, 0.5] } else { vec![0.0, 0.25, 0.5, 1.0] };
    let mut rows: Vec<Row> = Vec::new();
    for &loss in &loss_sweep {
        for &outage in &outage_sweep {
            let mut p = params;
            p.net.fault_seed = 7;
            p.net.loss_prob = loss;
            p.net.jitter_ms = 2.0;
            if outage > 0.0 {
                // Early enough that even the 24-frame smoke trace
                // (~0.27 s at 90 fps) sends rounds into the window.
                p.net.outage_start_s = 0.1;
                p.net.outage_period_s = 2.0;
                p.net.outage_len_s = outage;
            }
            let r = run_simulation(&tree, &poses, &Variant::nebula(), &p);
            // Recovery canaries: the client must come back within the
            // retry/resync budget in every cell — finite latency and
            // staleness percentiles, never NaN/inf.
            assert!(
                r.mtp_p99_ms.is_finite(),
                "CANARY: non-finite p99 MTP at loss={loss} outage={outage}"
            );
            assert!(
                r.faults.staleness_mean_frames.is_finite()
                    && r.faults.staleness_p99_frames.is_finite(),
                "CANARY: non-finite staleness at loss={loss} outage={outage}"
            );
            assert!(
                r.faults.recovery_frames_max <= frames as u64,
                "CANARY: recovery span exceeds the trace at loss={loss} outage={outage}"
            );
            println!(
                "  loss {loss:>4.2} outage {outage:>4.2}s: mtp p99 {:>7.2} ms, \
                 lost {:>3}, rexmit {:>3}, resync {:>2}, stalls {:>2}, \
                 stale p99 {:>5.1} f",
                r.mtp_p99_ms,
                r.faults.lost_msgs,
                r.faults.retransmits,
                r.faults.resyncs,
                r.faults.stalls,
                r.faults.staleness_p99_frames
            );
            rows.push(Row {
                loss_prob: loss,
                outage_len_s: outage,
                mtp_ms: r.mtp_ms,
                mtp_p99_ms: r.mtp_p99_ms,
                bandwidth_bps: r.bandwidth_bps,
                faults: r.faults,
            });
        }
    }
    // The heaviest cell must actually have exercised the fault path.
    let heavy = rows.last().unwrap();
    assert!(
        heavy.faults.lost_msgs > 0,
        "CANARY: heaviest cell (loss={} outage={}s) lost no messages",
        heavy.loss_prob,
        heavy.outage_len_s
    );

    // --- Thread-invariance canary on the heaviest cell ----------------
    let mut heavy_params = params;
    heavy_params.net.fault_seed = 7;
    heavy_params.net.loss_prob = *loss_sweep.last().unwrap();
    heavy_params.net.jitter_ms = 2.0;
    heavy_params.net.outage_start_s = 0.1;
    heavy_params.net.outage_period_s = 2.0;
    heavy_params.net.outage_len_s = *outage_sweep.last().unwrap();
    let t1 = run_simulation(&tree, &poses, &Variant::nebula(), &heavy_params);
    heavy_params.pipeline.threads = 2;
    let t2 = run_simulation(&tree, &poses, &Variant::nebula(), &heavy_params);
    assert_eq!(
        t1, t2,
        "PARITY VIOLATION: heaviest fault cell diverged between 1 and 2 threads"
    );
    println!("  parity: heaviest cell bitwise identical at 1 and 2 threads");

    // --- Multi-client degradation cell --------------------------------
    // Tight shared budgets + a mid-run disconnect: admission control
    // sheds, the uplink controller coarsens τ, and the dropped session
    // resyncs on reconnect — all deterministically countable.
    let clients = if smoke { 2 } else { 4 };
    let traces = benchkit::walk_traces(&spec, frames, clients);
    let mut mp = params;
    mp.net.fault_seed = 7;
    mp.net.loss_prob = 0.02;
    let gap = (frames / 4, frames / 2);
    let server = ServerConfig {
        cloud_budget: 0.05,
        uplink_bps: 50e6,
        max_cloud_lag_s: 0.05,
        degrade_lag_s: 0.01,
        disconnects: vec![Disconnect { session: 0, from_frame: gap.0, to_frame: gap.1 }],
    };
    let degraded = run_multiclient(&tree, &traces, &Variant::nebula(), &mp, &server);
    assert_eq!(
        degraded.faults.disconnected_frames,
        (gap.1 - gap.0) as u64,
        "CANARY: disconnect window not fully accounted"
    );
    assert!(
        degraded.faults.staleness_p99_frames.is_finite(),
        "CANARY: non-finite staleness in the degraded multi-client cell"
    );
    println!(
        "  degraded {clients}-client cell: shed {}, degraded {}, resyncs {}, \
         disconnected {} frames, stale p99 {:.1} f",
        degraded.faults.shed_rounds,
        degraded.faults.degraded_rounds,
        degraded.faults.resyncs,
        degraded.faults.disconnected_frames,
        degraded.faults.staleness_p99_frames
    );

    // --- JSON (hand-rolled; serde unavailable offline) -----------------
    let mut j = String::new();
    j.push_str("{\n");
    j.push_str("  \"bench\": \"faults\",\n");
    j.push_str(&format!(
        "  \"scene\": {{\"dataset\": \"{}\", \"target_gaussians\": {target}, \"frames\": {frames}}},\n",
        spec.name
    ));
    j.push_str("  \"faults\": [\n");
    for (i, r) in rows.iter().enumerate() {
        j.push_str(&format!(
            "    {{\"loss_prob\": {:.3}, \"outage_len_s\": {:.3}, \"mtp_ms\": {:.4}, \"mtp_p99_ms\": {:.4}, \"bandwidth_bps\": {:.0}, \"lost_msgs\": {}, \"retransmits\": {}, \"resyncs\": {}, \"stalls\": {}, \"staleness_mean_frames\": {:.4}, \"staleness_p99_frames\": {:.4}, \"recovery_frames_max\": {}}}{}\n",
            r.loss_prob,
            r.outage_len_s,
            r.mtp_ms,
            r.mtp_p99_ms,
            r.bandwidth_bps,
            r.faults.lost_msgs,
            r.faults.retransmits,
            r.faults.resyncs,
            r.faults.stalls,
            r.faults.staleness_mean_frames,
            r.faults.staleness_p99_frames,
            r.faults.recovery_frames_max,
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    j.push_str("  ],\n");
    j.push_str(&format!(
        "  \"degraded\": {{\"clients\": {clients}, \"shed_rounds\": {}, \"degraded_rounds\": {}, \"resyncs\": {}, \"stalls\": {}, \"disconnected_frames\": {}, \"staleness_p99_frames\": {:.4}, \"cloud_utilization\": {:.6}, \"uplink_utilization\": {:.6}}}\n",
        degraded.faults.shed_rounds,
        degraded.faults.degraded_rounds,
        degraded.faults.resyncs,
        degraded.faults.stalls,
        degraded.faults.disconnected_frames,
        degraded.faults.staleness_p99_frames,
        degraded.cloud_utilization,
        degraded.uplink_utilization
    ));
    j.push_str("}\n");

    let out_path =
        std::env::var("NEBULA_BENCH_OUT").unwrap_or_else(|_| "BENCH_faults.json".to_string());
    std::fs::write(&out_path, &j).expect("write bench json");
    println!("wrote {out_path}");
}
