//! Fig 17: rendering quality vs bandwidth — Nebula's Δcut compression
//! against H.265 video streaming at three quality levels.

use nebula::benchkit::{self, build_scene, walk_trace};
use nebula::compress::{CompressionMode, DeltaCodec, FixedQuantizer, VqTrainer};
use nebula::lod::{LodSearch, TemporalSearch};
use nebula::manage::protocol::{ClientEndpoint, CloudEndpoint};
use nebula::math::{Intrinsics, StereoCamera};
use nebula::net::{VideoCodec, VideoQuality};
use nebula::render::raster::RasterConfig;
use nebula::render::stereo::{render_stereo, StereoMode};
use nebula::scene::dataset;
use nebula::util::bench::bench_header;
use nebula::util::table::{fnum, human_bps, Table};

fn main() {
    bench_header("Fig 17", "quality vs bandwidth: Nebula Δcut compression vs H.265");
    let spec = dataset("urban").unwrap();
    let tree = build_scene(&spec);
    let pl = benchkit::calibrated_pipeline(&tree, &spec);
    let poses = walk_trace(&spec, 360);

    let mut t = Table::new(vec!["method", "PSNR dB (vs rendered)", "bandwidth @90FPS"]);
    // Video streaming rows.
    for q in VideoQuality::ALL {
        let codec = VideoCodec::vr_stereo(q, 2064, 2208, 90.0);
        t.row(vec![
            format!("H.265 {}", q.label()),
            fnum(q.psnr_db(), 1),
            human_bps(codec.bitrate_bps()),
        ]);
    }

    // Nebula rows: stream the walk, then measure decoded-render quality.
    for (label, mode) in
        [("Nebula (VQ+16b+zstd)", CompressionMode::Quantized), ("Nebula (raw+zstd)", CompressionMode::Raw)]
    {
        let (lo, hi) = tree.gaussians.bounds();
        let codec = DeltaCodec::new(
            mode,
            FixedQuantizer::for_bounds(lo, hi),
            VqTrainer::default().train(&tree.gaussians.sh),
        );
        let mut cloud = CloudEndpoint::new(&tree, codec, pl.reuse_threshold);
        let mut client =
            ClientEndpoint::from_init(&cloud.scene_init(), mode, pl.reuse_threshold).unwrap();
        let mut search = TemporalSearch::for_tree(&tree);
        let mut bytes = 0u64;
        for (i, pose) in poses.iter().enumerate().step_by(pl.lod_interval as usize) {
            let cut = search.search(&tree, &benchkit::query_at(pose, &pl));
            let msg = cloud.publish_cut(&cut.nodes);
            if i > 0 {
                bytes += msg.wire_bytes() as u64;
            }
            client.apply(&msg).unwrap();
        }
        let bw = bytes as f64 * 8.0 / (poses.len() as f64 / 90.0);

        // Quality: decoded store vs pristine render at the last pose.
        let pose = poses[poses.len() - 1];
        let cam = StereoCamera::new(pose, Intrinsics::vr_eye_scaled(16));
        let cfg = RasterConfig::default();
        let cut = benchkit::cut_at(&tree, &pose, &pl);
        let pristine = benchkit::queue_for(&tree, &cut);
        let a = render_stereo(&cam, &benchkit::queue_refs(&pristine), 3, pl.tile, &cfg, StereoMode::AlphaGated);
        let decoded = client.store.render_queue();
        let decoded_refs: Vec<_> = decoded.iter().map(|(id, g)| (*id, *g)).collect();
        let b = render_stereo(&cam, &decoded_refs, 3, pl.tile, &cfg, StereoMode::AlphaGated);
        t.row(vec![label.to_string(), fnum(a.left.psnr(&b.left), 1), human_bps(bw)]);
    }
    t.print();
    println!("paper: Nebula ≈ Lossy-H quality at a fraction of the bandwidth.");
}
