//! Fig 19: client energy savings and bandwidth requirement (normalized
//! to GPU / video streaming), averaged over large datasets.

use nebula::benchkit::{self, build_scene, walk_trace};
use nebula::coordinator::scheduler::{run_remote_simulation, run_simulation, SimParams};
use nebula::net::{VideoCodec, VideoQuality};
use nebula::scene::LARGE_DATASETS;
use nebula::util::bench::bench_header;
use nebula::util::table::{fnum, human_bps, Table};

fn main() {
    bench_header("Fig 19", "energy savings + bandwidth (vs GPU / video streaming)");
    let frames = 48;
    let variants = benchkit::fig18_variants();
    let video_bps =
        VideoCodec::vr_stereo(VideoQuality::LossyHigh, 2064, 2208, 90.0).bitrate_bps();

    let mut t = Table::new(vec!["variant", "E: energy saving vs GPU", "B: bandwidth", "% of video"]);
    let mut rows: Vec<(String, f64, f64)> = Vec::new();
    for spec in LARGE_DATASETS {
        let tree = build_scene(&spec);
        let mut params = SimParams::default();
        params.pipeline = benchkit::calibrated_pipeline(&tree, &spec);
        params.pipeline.res_scale = 16;
        let poses = walk_trace(&spec, frames);
        let mut gpu_energy = 0.0;
        for (i, v) in variants.iter().enumerate() {
            let r = run_simulation(&tree, &poses, v, &params);
            if i == 0 {
                gpu_energy = r.client_energy_j;
            }
            // Bandwidth to sustain 90 FPS: steady wire bytes scaled to 90 FPS rounds.
            if rows.len() < variants.len() + 1 {
                rows.push((v.name.clone(), 0.0, 0.0));
            }
            rows[i].1 += gpu_energy / r.client_energy_j;
            rows[i].2 += r.bandwidth_bps;
        }
        let remote = run_remote_simulation(&params, VideoQuality::LossyHigh, frames as u32);
        if rows.len() < variants.len() + 1 {
            rows.push(("Remote (Lossy-H)".into(), 0.0, 0.0));
        }
        let last = rows.len() - 1;
        rows[last].1 += gpu_energy / remote.client_energy_j;
        rows[last].2 += remote.bandwidth_bps;
    }
    let n = LARGE_DATASETS.len() as f64;
    for (name, e, b) in &rows {
        t.row(vec![
            name.clone(),
            fnum(e / n, 2),
            human_bps(b / n),
            fnum(b / n / video_bps * 100.0, 1),
        ]);
    }
    t.print();
    println!(
        "paper: Remote saves the most client energy (38.4x, wireless only) but needs the \
         full video bandwidth; Nebula saves 14.9x vs GPU at 19-25% of video bandwidth."
    );
}
