//! Fig 2: GPU memory footprint vs scene scale.
//!
//! Paper: runtime memory grows from <1 GB (small datasets) to 66 GB
//! (HierGS), exceeding the <12 GB of VR devices. We report both the
//! instantiated simulation footprint and the full-scale extrapolation
//! (registry `paper_full_gaussians` × bytes/Gaussian).

use nebula::benchkit::build_scene;
use nebula::gaussian::BYTES_PER_GAUSSIAN;
use nebula::scene::ALL_DATASETS;
use nebula::util::bench::bench_header;
use nebula::util::table::{human_bytes, Table};

fn main() {
    bench_header("Fig 2", "memory footprint vs scene scale");
    let mut t = Table::new(vec![
        "dataset", "scale", "sim nodes", "sim memory", "full-scale memory", "fits 12GB VR?",
    ]);
    const VR: u64 = 12 * (1 << 30);
    for spec in ALL_DATASETS {
        let tree = build_scene(&spec);
        let full = spec.paper_full_gaussians * BYTES_PER_GAUSSIAN as u64;
        t.row(vec![
            spec.name.to_string(),
            if spec.large_scale { "large" } else { "small" }.to_string(),
            tree.len().to_string(),
            human_bytes(tree.byte_size()),
            human_bytes(full),
            if full < VR { "yes" } else { "NO" }.to_string(),
        ]);
    }
    t.print();
    println!("paper: all large-scale scenes exceed VR memory; HierGS peaks at 66 GB.");
}
