//! BENCH_chaos: the composed chaos soak — the streaming path swept over
//! corruption rate × loss rate, a poison-link quarantine cell, and a
//! multi-client soak with every fault axis live at once (loss ×
//! corruption × outage × bandwidth dips × memory pressure × disconnect
//! × τ-degradation). Writes `BENCH_chaos.json` with a `"chaos"` section
//! (per cell: MTP percentiles, bandwidth, fault + integrity counters)
//! and a `"soak"` section for the composed multi-client cell.
//!
//!     cargo bench --bench bench_chaos [-- --smoke]
//!
//! `--smoke` is the CI canary: a minimal scene and a 2×2 sweep, but
//! every integrity assertion still executes:
//! * zero-chaos runs (nonzero seed, changed quarantine budget, all
//!   probabilities zero) reproduce the faultless baseline
//!   field-for-field with all-zero integrity counters — the CRC
//!   trailers are wire-free by construction;
//! * `corrupt_passed == 0` in EVERY cell — no damaged frame ever
//!   applies silently while checksums are on;
//! * the poison cell (corrupt_prob = 1.0) quarantines every round
//!   within exactly `quarantine_after` damaged copies — bounded
//!   recovery, never a livelock;
//! * the composed soak is bitwise identical at 1 and 2 threads.
//!
//! Env knobs: `NEBULA_BENCH_SCALE` (scene divisor, default 8),
//! `NEBULA_BENCH_OUT` (output path, default `BENCH_chaos.json`).

use nebula::benchkit;
use nebula::coordinator::scheduler::{run_simulation, SimParams};
use nebula::coordinator::{
    run_multiclient, Disconnect, IntegrityCounters, ServerConfig, Variant,
};
use nebula::scene::{dataset, CityGen};
use nebula::util::bench::bench_header;

struct Row {
    corrupt_prob: f64,
    loss_prob: f64,
    mtp_ms: f64,
    mtp_p99_ms: f64,
    bandwidth_bps: f64,
    lost_msgs: u64,
    stalls: u64,
    resyncs: u64,
    staleness_p99_frames: f64,
    integrity: IntegrityCounters,
}

fn main() {
    bench_header("BENCH_chaos", "composed chaos soak: corruption x loss + all-axes cell");
    let smoke = std::env::args().any(|a| a == "--smoke");
    if smoke {
        println!("smoke mode: minimal scene, 2x2 corruption x loss sweep");
    }
    let spec = dataset("urban").unwrap();
    let target = (spec.sim_gaussians / benchkit::bench_scale() / if smoke { 4 } else { 1 })
        .max(10_000);
    let tree = CityGen::new(spec.city_params(target)).build();
    let mut params = SimParams::default();
    params.pipeline = benchkit::calibrated_pipeline(&tree, &spec);
    params.pipeline.res_scale = 16;
    params.pipeline.threads = 1;
    let frames = if smoke { 24 } else { 96 };
    let poses = benchkit::walk_trace(&spec, frames);
    println!("scene: {} Gaussians, {frames}-frame trace", tree.len());

    // --- Parity canary: zero-chaos plan == faultless baseline ----------
    // A nonzero seed and a changed quarantine budget with every fault
    // probability zero must not perturb a single field — the checksum
    // trailers ride inside the already-charged header bytes.
    let baseline = run_simulation(&tree, &poses, &Variant::nebula(), &params);
    let mut zeroed = params;
    zeroed.net.fault_seed = 0xDEAD_BEEF;
    zeroed.net.quarantine_after = 7;
    zeroed.net.dip_factor = 1.0; // a factor of 1.0 is a no-op dip
    let zero_chaos = run_simulation(&tree, &poses, &Variant::nebula(), &zeroed);
    assert_eq!(
        zero_chaos, baseline,
        "PARITY VIOLATION: idle integrity machinery diverged from the faultless baseline"
    );
    assert_eq!(
        baseline.integrity,
        IntegrityCounters::default(),
        "CANARY: faultless run must report all-zero integrity counters"
    );
    println!("  parity: zero-chaos plan == faultless baseline (field-for-field)");

    // --- Corruption x loss sweep ---------------------------------------
    // The heaviest cell is 0.9, not ~0.3: the smoke trace publishes only
    // a handful of rounds, and the heaviest-cell canary below needs the
    // corruption axis to have provably fired.
    let corrupt_sweep: Vec<f64> =
        if smoke { vec![0.0, 0.9] } else { vec![0.0, 0.05, 0.3, 0.9] };
    let loss_sweep: Vec<f64> = if smoke { vec![0.0, 0.05] } else { vec![0.0, 0.02, 0.05] };
    let mut rows: Vec<Row> = Vec::new();
    for &corrupt in &corrupt_sweep {
        for &loss in &loss_sweep {
            let mut p = params;
            p.net.fault_seed = 17;
            p.net.corrupt_prob = corrupt;
            p.net.loss_prob = loss;
            p.net.jitter_ms = 2.0;
            p.net.quarantine_after = 3;
            let r = run_simulation(&tree, &poses, &Variant::nebula(), &p);
            // Integrity canaries, every cell: silent corruption is
            // impossible with checksums on, NACK accounting is exact,
            // and the client recovers within the trace.
            assert_eq!(
                r.integrity.corrupt_passed, 0,
                "CANARY: silent corruption at corrupt={corrupt} loss={loss}"
            );
            assert_eq!(
                r.integrity.nack_bytes,
                r.integrity.corrupt_detected * 16,
                "CANARY: NACK byte accounting broke at corrupt={corrupt} loss={loss}"
            );
            assert!(
                r.mtp_p99_ms.is_finite() && r.faults.staleness_p99_frames.is_finite(),
                "CANARY: non-finite accounting at corrupt={corrupt} loss={loss}"
            );
            assert!(
                r.faults.recovery_frames_max <= frames as u64,
                "CANARY: recovery span exceeds the trace at corrupt={corrupt} loss={loss}"
            );
            println!(
                "  corrupt {corrupt:>4.2} loss {loss:>4.2}: mtp p99 {:>7.2} ms, \
                 detected {:>3}, quarantined {:>2}, nack {:>5} B, stalls {:>2}, \
                 stale p99 {:>5.1} f",
                r.mtp_p99_ms,
                r.integrity.corrupt_detected,
                r.integrity.quarantined_rounds,
                r.integrity.nack_bytes,
                r.faults.stalls,
                r.faults.staleness_p99_frames
            );
            rows.push(Row {
                corrupt_prob: corrupt,
                loss_prob: loss,
                mtp_ms: r.mtp_ms,
                mtp_p99_ms: r.mtp_p99_ms,
                bandwidth_bps: r.bandwidth_bps,
                lost_msgs: r.faults.lost_msgs,
                stalls: r.faults.stalls,
                resyncs: r.faults.resyncs,
                staleness_p99_frames: r.faults.staleness_p99_frames,
                integrity: r.integrity,
            });
        }
    }
    // The heaviest corruption cell must actually have exercised the
    // detection path.
    let heavy = rows.last().unwrap();
    assert!(
        heavy.integrity.corrupt_detected > 0,
        "CANARY: heaviest cell (corrupt={} loss={}) detected no corruption",
        heavy.corrupt_prob,
        heavy.loss_prob
    );

    // --- Poison cell: every delivery damaged ---------------------------
    // corrupt_prob = 1.0 is the livelock stress: each round must be
    // quarantined after exactly `quarantine_after` damaged copies (at
    // most one round still mid-NACK when the trace ends) and the frame
    // loop must run to completion on the round-0 prefetch.
    let mut pp = params;
    pp.net.fault_seed = 5;
    pp.net.corrupt_prob = 1.0;
    pp.net.quarantine_after = 2;
    let q = pp.net.quarantine_after as u64;
    let poison = run_simulation(&tree, &poses, &Variant::nebula(), &pp);
    assert_eq!(
        poison.frames as usize,
        poses.len(),
        "CANARY: poison link stalled the frame loop"
    );
    assert_eq!(poison.integrity.corrupt_passed, 0);
    assert!(poison.integrity.quarantined_rounds > 0, "CANARY: poison link never quarantined");
    assert!(
        poison.integrity.corrupt_detected >= poison.integrity.quarantined_rounds * q
            && poison.integrity.corrupt_detected <= (poison.integrity.quarantined_rounds + 1) * q,
        "CANARY: quarantine bound violated ({} detections for {} quarantined rounds, q={q})",
        poison.integrity.corrupt_detected,
        poison.integrity.quarantined_rounds
    );
    println!(
        "  poison cell: {} rounds quarantined after exactly {q} damaged copies each \
         ({} detections), frame loop completed",
        poison.integrity.quarantined_rounds, poison.integrity.corrupt_detected
    );

    // --- Composed multi-client soak ------------------------------------
    // Every axis live at once: loss + jitter + outage + bandwidth dips +
    // corruption + a hard client memory budget + a mid-run disconnect +
    // admission control and τ-degradation.
    let clients = if smoke { 2 } else { 4 };
    let traces = benchkit::walk_traces(&spec, frames, clients);
    let mut sp = params;
    sp.net.fault_seed = 23;
    sp.net.loss_prob = 0.05;
    sp.net.jitter_ms = 2.0;
    sp.net.outage_start_s = 0.1;
    sp.net.outage_period_s = 2.0;
    sp.net.outage_len_s = 0.15;
    sp.net.dip_period_s = 0.4;
    sp.net.dip_len_s = 0.1;
    sp.net.dip_factor = 0.35;
    sp.net.corrupt_prob = 0.3;
    sp.net.quarantine_after = 2;
    sp.pipeline.client_mem_mb = 0.08;
    let gap = (frames / 4, frames / 2);
    let server = ServerConfig {
        cloud_budget: 0.25,
        uplink_bps: 200e6,
        max_cloud_lag_s: 0.05,
        degrade_lag_s: 0.02,
        disconnects: vec![Disconnect { session: 1, from_frame: gap.0, to_frame: gap.1 }],
    };
    let soak = run_multiclient(&tree, &traces, &Variant::nebula(), &sp, &server);
    assert_eq!(
        soak.integrity.corrupt_passed, 0,
        "CANARY: silent corruption in the composed soak"
    );
    assert_eq!(
        soak.faults.disconnected_frames,
        (gap.1 - gap.0) as u64,
        "CANARY: disconnect window not fully accounted in the soak"
    );
    assert!(
        soak.faults.staleness_p99_frames.is_finite(),
        "CANARY: non-finite staleness in the composed soak"
    );
    for (i, c) in soak.per_client.iter().enumerate() {
        assert_eq!(
            c.frames as u64, frames as u64,
            "CANARY: soak client {i} did not finish its trace"
        );
        assert!(c.mtp_p99_ms.is_finite(), "CANARY: soak client {i} accounting broke");
    }
    println!(
        "  soak {clients}-client cell: detected {}, quarantined {}, lost {}, \
         shed {}, degraded {}, evicted {}, disconnected {} frames",
        soak.integrity.corrupt_detected,
        soak.integrity.quarantined_rounds,
        soak.faults.lost_msgs,
        soak.faults.shed_rounds,
        soak.faults.degraded_rounds,
        soak.mem.capacity_evictions,
        soak.faults.disconnected_frames
    );

    // --- Thread-invariance canary on the composed soak -----------------
    let mut sp2 = sp;
    sp2.pipeline.threads = 2;
    let soak2 = run_multiclient(&tree, &traces, &Variant::nebula(), &sp2, &server);
    assert_eq!(
        soak2, soak,
        "PARITY VIOLATION: composed soak diverged between 1 and 2 threads"
    );
    println!("  parity: composed soak bitwise identical at 1 and 2 threads");

    // --- JSON (hand-rolled; serde unavailable offline) -----------------
    let mut j = String::new();
    j.push_str("{\n");
    j.push_str("  \"bench\": \"chaos\",\n");
    j.push_str(&format!(
        "  \"scene\": {{\"dataset\": \"{}\", \"target_gaussians\": {target}, \"frames\": {frames}}},\n",
        spec.name
    ));
    j.push_str("  \"chaos\": [\n");
    for (i, r) in rows.iter().enumerate() {
        j.push_str(&format!(
            "    {{\"corrupt_prob\": {:.3}, \"loss_prob\": {:.3}, \"mtp_ms\": {:.4}, \"mtp_p99_ms\": {:.4}, \"bandwidth_bps\": {:.0}, \"lost_msgs\": {}, \"stalls\": {}, \"resyncs\": {}, \"staleness_p99_frames\": {:.4}, \"corrupt_detected\": {}, \"corrupt_passed\": {}, \"quarantined_rounds\": {}, \"nack_bytes\": {}}}{}\n",
            r.corrupt_prob,
            r.loss_prob,
            r.mtp_ms,
            r.mtp_p99_ms,
            r.bandwidth_bps,
            r.lost_msgs,
            r.stalls,
            r.resyncs,
            r.staleness_p99_frames,
            r.integrity.corrupt_detected,
            r.integrity.corrupt_passed,
            r.integrity.quarantined_rounds,
            r.integrity.nack_bytes,
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    j.push_str("  ],\n");
    j.push_str(&format!(
        "  \"poison\": {{\"quarantine_after\": {q}, \"quarantined_rounds\": {}, \"corrupt_detected\": {}, \"nack_bytes\": {}, \"stalls\": {}, \"resyncs\": {}}},\n",
        poison.integrity.quarantined_rounds,
        poison.integrity.corrupt_detected,
        poison.integrity.nack_bytes,
        poison.faults.stalls,
        poison.faults.resyncs
    ));
    j.push_str(&format!(
        "  \"soak\": {{\"clients\": {clients}, \"corrupt_detected\": {}, \"corrupt_passed\": {}, \"quarantined_rounds\": {}, \"nack_bytes\": {}, \"lost_msgs\": {}, \"shed_rounds\": {}, \"degraded_rounds\": {}, \"capacity_evictions\": {}, \"disconnected_frames\": {}, \"staleness_p99_frames\": {:.4}, \"cloud_utilization\": {:.6}, \"uplink_utilization\": {:.6}}}\n",
        soak.integrity.corrupt_detected,
        soak.integrity.corrupt_passed,
        soak.integrity.quarantined_rounds,
        soak.integrity.nack_bytes,
        soak.faults.lost_msgs,
        soak.faults.shed_rounds,
        soak.faults.degraded_rounds,
        soak.mem.capacity_evictions,
        soak.faults.disconnected_frames,
        soak.faults.staleness_p99_frames,
        soak.cloud_utilization,
        soak.uplink_utilization
    ));
    j.push_str("}\n");

    let out_path =
        std::env::var("NEBULA_BENCH_OUT").unwrap_or_else(|_| "BENCH_chaos.json".to_string());
    std::fs::write(&out_path, &j).expect("write bench json");
    println!("wrote {out_path}");
}
