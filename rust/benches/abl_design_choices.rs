//! Extra ablations for the design choices DESIGN.md calls out (not a
//! paper figure):
//! 1. streaming blocked BFS vs pointer-chasing DFS (traversal layout);
//! 2. stereo line-buffer banking vs flat buffer (bank conflicts);
//! 3. merge-unit reuse vs re-sorting the right-eye lists;
//! 4. VQ codebook size vs quality/size.

use nebula::benchkit::{self, build_scene, walk_trace};
use nebula::compress::{CompressionMode, DeltaCodec, FixedQuantizer, VqTrainer};
use nebula::hw::{AccelConfig, AccelKind, Accelerator, FrameWorkload, Platform};
use nebula::lod::{FullSearch, LodSearch, StreamingSearch};
use nebula::scene::dataset;
use nebula::util::bench::{bench_header, Bencher};
use nebula::util::table::{fnum, Table};

fn main() {
    let spec = dataset("hiergs").unwrap();
    let tree = build_scene(&spec);
    let pl = benchkit::calibrated_pipeline(&tree, &spec);
    let poses = walk_trace(&spec, 8);
    let b = Bencher::new(5, 1);

    bench_header("Ablation 1", "streaming blocked BFS vs pointer-chasing DFS");
    let queries: Vec<_> = poses.iter().map(|p| benchkit::query_at(p, &pl)).collect();
    let mut t = Table::new(vec!["traversal", "ms/search"]);
    let dfs = b.run(|| {
        let mut s = FullSearch::new();
        queries.iter().map(|q| s.search(&tree, q).len()).sum::<usize>()
    });
    let bfs = b.run(|| {
        let mut s = StreamingSearch::default();
        queries.iter().map(|q| s.search(&tree, q).len()).sum::<usize>()
    });
    t.row(vec!["dfs (pointer-chase)".to_string(), fnum(dfs.median_ms() / queries.len() as f64, 3)]);
    t.row(vec!["streaming bfs".to_string(), fnum(bfs.median_ms() / queries.len() as f64, 3)]);
    t.print();

    bench_header("Ablation 2", "stereo buffer banking (Fig 15) on/off");
    let wl = FrameWorkload {
        preprocessed: 100_000,
        sorted: 100_000,
        alpha_checks: 40_000_000,
        blends: 8_000_000,
        pairs: 800_000,
        sru_insertions: 30_000_000,
        merge_ops: 9_000_000,
        pixels: 2 * 2064 * 2208,
        shared_preproc: true,
        ..Default::default()
    };
    let banked = Accelerator::new(AccelKind::Nebula, AccelConfig::default()).frame_cost(&wl);
    let flat = Accelerator::new(
        AccelKind::Nebula,
        AccelConfig { stereo_banked: false, ..Default::default() },
    )
    .frame_cost(&wl);
    let mut t = Table::new(vec!["stereo buffer", "frame ms", "slowdown"]);
    t.row(vec!["line-buffer banked".into(), fnum(banked.seconds * 1e3, 2), "1.00".into()]);
    t.row(vec![
        "flat (conflicting)".into(),
        fnum(flat.seconds * 1e3, 2),
        fnum(flat.seconds / banked.seconds, 2),
    ]);
    t.print();

    bench_header("Ablation 3", "merge-of-4 vs re-sort of right-eye lists");
    // Merge does O(n·L) comparisons; re-sorting does O(n log n) with a
    // larger constant — count both on the measured list sizes.
    let n_lists = 9_000_000u64;
    let merge_ops = n_lists * 4;
    let resort_ops = (n_lists as f64 * (n_lists as f64 / 35_000.0).log2() * 1.8) as u64;
    let mut t = Table::new(vec!["right-eye ordering", "ops", "vs merge"]);
    t.row(vec!["merge unit (paper)".into(), merge_ops.to_string(), "1.0".into()]);
    t.row(vec![
        "re-sort".into(),
        resort_ops.to_string(),
        fnum(resort_ops as f64 / merge_ops as f64, 1),
    ]);
    t.print();

    bench_header("Ablation 4", "VQ codebook size vs Δcut size");
    let (lo, hi) = tree.gaussians.bounds();
    let ids: Vec<u32> = (0..tree.len().min(3000) as u32).collect();
    let items: Vec<_> = ids.iter().map(|&id| (id, tree.gaussians.record(id))).collect();
    let mut t = Table::new(vec!["codebook", "bytes/Gaussian", "SH rest MSE"]);
    for size in [16usize, 64, 256, 1024] {
        let cb = VqTrainer { codebook_size: size, ..Default::default() }.train(&tree.gaussians.sh);
        // Quality: mean squared decode error over a sample.
        let mut mse = 0.0f64;
        for (_, g) in items.iter().take(400) {
            let v = nebula::compress::vq::sh_rest(&g.sh);
            let e = cb.entry(cb.encode(&v));
            mse += v.iter().zip(e).map(|(a, b)| ((a - b) * (a - b)) as f64).sum::<f64>();
        }
        mse /= 400.0 * 45.0;
        let codec =
            DeltaCodec::new(CompressionMode::Quantized, FixedQuantizer::for_bounds(lo, hi), cb);
        let enc = codec.encode(&items);
        t.row(vec![
            size.to_string(),
            fnum(enc.wire_bytes() as f64 / items.len() as f64, 1),
            fnum(mse, 5),
        ]);
    }
    t.print();
}
