//! Fig 5: video-streaming bandwidth vs resolution for H.265 Lossy-L/H and
//! Lossless, against the average US household link (~280 Mbps, red line).

use nebula::net::{VideoCodec, VideoQuality};
use nebula::util::bench::bench_header;
use nebula::util::table::{human_bps, Table};

fn main() {
    bench_header("Fig 5", "bandwidth vs resolution (stereo 90 FPS)");
    const HOUSEHOLD_BPS: f64 = 280e6;
    let mut t = Table::new(vec!["per-eye resolution", "Lossy-L", "Lossy-H", "Lossless", "over household link?"]);
    for (w, h, label) in [
        (1280u32, 1440u32, "1280x1440"),
        (1832, 1920, "1832x1920 (Quest 2)"),
        (2064, 2208, "2064x2208 (Quest 3)"),
        (2880, 2880, "2880x2880 (Vision-class)"),
    ] {
        let rates: Vec<f64> = VideoQuality::ALL
            .iter()
            .map(|&q| VideoCodec::vr_stereo(q, w, h, 90.0).bitrate_bps())
            .collect();
        t.row(vec![
            label.to_string(),
            human_bps(rates[0]),
            human_bps(rates[1]),
            human_bps(rates[2]),
            if rates[1] > HOUSEHOLD_BPS { "Lossy-H exceeds" } else { "fits" }.to_string(),
        ]);
    }
    t.print();
    println!("red line: avg US household ≈ {}", human_bps(HOUSEHOLD_BPS));
}
