//! Fig 25: stereo-raster speedup sensitivity to the tile size on the
//! GPU — larger tiles suffer more warp divergence in the baseline, so
//! stereo rasterization (which prunes diverging α-failures) helps more;
//! the speedup shrinks as tiles shrink.

use nebula::benchkit::{self, build_scene, walk_trace};
use nebula::hw::{FrameWorkload, MobileGpu, Platform};
use nebula::math::{Intrinsics, StereoCamera};
use nebula::render::raster::{render_mono, RasterConfig};
use nebula::render::stereo::{render_stereo, StereoMode};
use nebula::render::{preprocess_records, Parallelism};
use nebula::scene::dataset;
use nebula::util::bench::bench_header;
use nebula::util::table::{fnum, Table};

fn main() {
    bench_header("Fig 25", "stereo speedup vs tile size (GPU, HierGS-analogue)");
    let spec = dataset("hiergs").unwrap();
    let tree = build_scene(&spec);
    let pl = benchkit::calibrated_pipeline(&tree, &spec);
    let pose = walk_trace(&spec, 12)[11];
    let cam = StereoCamera::new(pose, Intrinsics::vr_eye_scaled(16));
    let cut = benchkit::cut_at(&tree, &pose, &pl);
    let queue = benchkit::queue_for(&tree, &cut);
    let refs = benchkit::queue_refs(&queue);
    let cfg = RasterConfig::default();
    let pixels = 2 * Intrinsics::vr_eye().pixels();

    let mut t = Table::new(vec!["tile", "base ms", "stereo ms", "speedup"]);
    for tile in [4u32, 8, 16, 32] {
        let lset = preprocess_records(&cam.left(), &cam.left(), &refs, 3, Parallelism::auto());
        let rset = preprocess_records(&cam.right(), &cam.right(), &refs, 3, Parallelism::auto());
        let count = (lset.splats.len() + rset.splats.len()) / 2;
        let (_, ls, _) = render_mono(lset, cam.intr.width, cam.intr.height, tile, &cfg);
        let (_, rs, _) = render_mono(rset, cam.intr.width, cam.intr.height, tile, &cfg);
        let base_wl = FrameWorkload::from_mono_pair(count, &ls, &rs, pixels);
        let out = render_stereo(&cam, &refs, 3, tile, &cfg, StereoMode::AlphaGated);
        let stereo_wl = FrameWorkload::from_stereo(&out, pixels);

        let gpu = MobileGpu::orin().with_tile(tile);
        let base = gpu.frame_cost(&base_wl).seconds;
        let stereo = gpu.frame_cost(&stereo_wl).seconds;
        t.row(vec![
            format!("{tile}x{tile}"),
            fnum(base * 1e3, 2),
            fnum(stereo * 1e3, 2),
            fnum(base / stereo, 2),
        ]);
    }
    t.print();
    println!("paper: speedup decreases modestly with smaller tiles (less divergence to save).");
}
