//! Fig 4: end-to-end breakdown of REMOTE rendering (video streaming):
//! data transmission dominates at 90 FPS VR resolution.

use nebula::net::channel::SimLink;
use nebula::net::{VideoCodec, VideoQuality};
use nebula::util::bench::bench_header;
use nebula::util::table::{fnum, Table};

fn main() {
    bench_header("Fig 4", "remote rendering (video streaming) breakdown");
    let mut t = Table::new(vec![
        "quality", "render %", "transmit %", "codec %", "frame ms", "sustains 90 FPS?",
    ]);
    let link = SimLink::new(100e6, 0.005);
    let server_render_s = 0.004; // two A100s
    for q in VideoQuality::ALL {
        let codec = VideoCodec::vr_stereo(q, 2064, 2208, 90.0);
        let tx = link.serialize_time(codec.bytes_per_frame()) + 0.005;
        let total = server_render_s + tx + codec.codec_latency_s();
        t.row(vec![
            q.label().to_string(),
            fnum(100.0 * server_render_s / total, 1),
            fnum(100.0 * tx / total, 1),
            fnum(100.0 * codec.codec_latency_s() / total, 1),
            fnum(total * 1e3, 1),
            if link.sustains(codec.bytes_per_frame(), 1.0 / 90.0) { "yes" } else { "NO" }
                .to_string(),
        ]);
    }
    t.print();
    println!("paper: transmission dominates remote rendering at VR resolution.");
}
