//! Fig 6: runtime memory demand per pipeline stage (Gaussian counts as
//! the proxy): LoD search touches the whole scene, everything after the
//! cut is small — the observation that motivates offloading LoD search.

use nebula::benchkit::{self, build_scene, walk_trace};
use nebula::lod::{LodSearch, StreamingSearch};
use nebula::math::{Intrinsics, StereoCamera};
use nebula::render::{preprocess_records, Parallelism};
use nebula::scene::LARGE_DATASETS;
use nebula::util::bench::bench_header;
use nebula::util::table::{fnum, Table};

fn main() {
    bench_header("Fig 6", "per-stage memory demand (Gaussians touched)");
    let mut t = Table::new(vec![
        "dataset", "LoD search", "preprocess", "sort+raster", "search/raster ratio",
    ]);
    for spec in LARGE_DATASETS {
        let tree = build_scene(&spec);
        let pl = benchkit::calibrated_pipeline(&tree, &spec);
        let pose = walk_trace(&spec, 8)[7];
        let cut = StreamingSearch::default().search(&tree, &benchkit::query_at(&pose, &pl));
        // LoD search stage must be able to touch the whole model.
        let lod_gaussians = tree.len();
        let queue = benchkit::queue_for(&tree, &cut.nodes);
        let cam = StereoCamera::new(pose, Intrinsics::vr_eye_scaled(16));
        let shared = StereoCamera::new(pose, cam.intr).shared_camera();
        let set = preprocess_records(&cam.left(), &shared, &benchkit::queue_refs(&queue), 3, Parallelism::auto());
        t.row(vec![
            spec.name.to_string(),
            lod_gaussians.to_string(),
            cut.len().to_string(),
            set.splats.len().to_string(),
            fnum(lod_gaussians as f64 / set.splats.len().max(1) as f64, 1),
        ]);
    }
    t.print();
    println!("paper: memory peaks at LoD search, then drops to mobile-friendly sizes.");
}
