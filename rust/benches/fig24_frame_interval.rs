//! Fig 24: bandwidth sensitivity to the LoD-search interval w — the
//! demand rises only modestly as w shrinks (payload is churn-bound, not
//! round-bound).

use nebula::benchkit::{self, build_scene};
use nebula::coordinator::metrics::Variant;
use nebula::coordinator::scheduler::{run_simulation, SimParams};
use nebula::scene::LARGE_DATASETS;
use nebula::trace::{PoseTrace, TraceParams};
use nebula::util::bench::bench_header;
use nebula::util::table::{fnum, human_bps, Table};

fn main() {
    bench_header("Fig 24", "bandwidth vs LoD interval w (90 FPS)");
    let mut t = Table::new(vec!["dataset", "w=1", "w=2", "w=4 (default)", "w=8", "w=16"]);
    for spec in LARGE_DATASETS {
        let tree = build_scene(&spec);
        let mut params = SimParams::default();
        params.pipeline = benchkit::calibrated_pipeline(&tree, &spec);
        params.pipeline.res_scale = 16;
        // Fast walk: enough churn that the payload dominates headers.
        let poses = PoseTrace::new(
            TraceParams { speed_mps: 5.0, seed: spec.seed, ..Default::default() },
            spec.extent_m,
        )
        .generate(270);
        let mut cells = vec![spec.name.to_string()];
        for w in [1u32, 2, 4, 8, 16] {
            params.pipeline.lod_interval = w;
            let r = run_simulation(&tree, &poses, &Variant::nebula(), &params);
            cells.push(human_bps(r.bandwidth_bps));
        }
        t.row(cells);
    }
    t.print();
    println!("paper: bandwidth grows only modestly as w decreases.");
    let _ = fnum(0.0, 0);
}
