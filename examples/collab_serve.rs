//! End-to-end driver: the full three-layer system on a real workload.
//!
//! * Cloud thread (L3): temporal-aware LoD search + Gaussian management
//!   + Δcut compression (zstd+VQ), streamed over a simulated 100 Mbps
//!   link.
//! * Client (L3 + runtime): decodes Δcuts, maintains the local store,
//!   and renders stereo frames. Preprocessing and tile rasterization run
//!   on the **AOT-compiled HLO artifacts** (L2 JAX graph calling the L1
//!   Pallas kernel) through the PJRT CPU client — Python is never in the
//!   loop.
//!
//! Reports per-frame motion-to-photon latency, FPS, and bandwidth;
//! results are recorded in EXPERIMENTS.md §E2E.
//!
//!     make artifacts && cargo run --release --example collab_serve

use nebula::benchkit;
use nebula::compress::CompressionMode;
use nebula::config::PipelineConfig;
use nebula::coordinator::live::{client_for, spawn_cloud};
use nebula::math::{Intrinsics, StereoCamera};
use nebula::net::channel::SimLink;
use nebula::render::raster::RasterConfig;
use nebula::render::stereo::render_stereo_from_splats;
use nebula::render::stereo::StereoMode;
use nebula::render::ProjectedSet;
use nebula::runtime::{ArtifactRuntime, PREPROCESS_CHUNK};
use nebula::scene::dataset;
use nebula::util::cli::Args;
use nebula::util::table::{fnum, human_bps, human_bytes, Table};
use nebula::util::Stopwatch;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let spec = dataset(args.get_or("scene", "urban"))?;
    let gaussians = args.get_parse_or("gaussians", 120_000usize);
    let frames = args.get_parse_or("frames", 48usize);
    let mut pl = PipelineConfig::default();
    pl.res_scale = args.get_parse_or("res-scale", 16);

    let rt = ArtifactRuntime::load(args.get_or("artifacts", "artifacts"))
        .map_err(|e| anyhow::anyhow!("{e}\nrun `make artifacts` first"))?;
    println!("PJRT platform: {}", rt.platform());

    println!("building '{}' at {gaussians} Gaussians ...", spec.name);
    let tree = Arc::new(nebula::scene::CityGen::new(spec.city_params(gaussians)).build());
    pl.tau_px = benchkit::calibrate_tau(&tree, spec.extent_m);
    let full_intr = Intrinsics::vr_eye();
    let intr = Intrinsics::vr_eye_scaled(pl.res_scale);
    let cfg = RasterConfig {
        alpha_min: pl.alpha_min,
        t_min: pl.transmittance_min,
        ..RasterConfig::default()
    };

    // --- Cloud service on its own thread -------------------------------
    let handle = spawn_cloud(tree.clone(), pl, CompressionMode::Quantized, full_intr.fx, full_intr.near);
    let mut client = client_for(&handle, CompressionMode::Quantized, pl.reuse_threshold);
    let mut link = SimLink::new(100e6, 0.005);

    let poses = benchkit::walk_trace(&spec, frames);
    // Initial scene load.
    handle.request_round(poses[0].position);
    let round0 = handle.next_round();
    let init_bytes = round0.msg.wire_bytes() as u64;
    client.apply(&round0.msg)?;
    println!(
        "initial Δcut: {} Gaussians, {} on the wire ({:.0} ms at 100 Mbps)\n",
        round0.msg.payload.count,
        human_bytes(init_bytes),
        link.serialize_time(init_bytes) * 1e3
    );

    let mut table = Table::new(vec!["frame", "queue", "splats", "render ms", "MTP ms", "Δ wire"]);
    let vsync = 1.0 / 90.0;
    let mut wire_total = 0u64;
    let mut mtp_sum = 0.0;
    let mut render_sum = 0.0;

    for (i, pose) in poses.iter().enumerate() {
        let t_frame = i as f64 * vsync;
        let mut wire = 0u64;
        // LoD round every w frames.
        if i > 0 && i % pl.lod_interval as usize == 0 {
            handle.request_round(pose.position);
            let round = handle.next_round();
            wire = round.msg.wire_bytes() as u64;
            wire_total += wire;
            link.send(t_frame, wire);
            client.apply(&round.msg)?;
        }

        // --- Client render through the HLO artifacts -------------------
        let sw = Stopwatch::start();
        let queue = client.store.render_queue();
        let cam = StereoCamera::new(*pose, intr);
        let left_cam = cam.left();
        let cam_params = ArtifactRuntime::cam_params(&left_cam);

        // Chunked HLO preprocessing (L2 graph on PJRT).
        let mut set = ProjectedSet::default();
        let mut ids = Vec::with_capacity(PREPROCESS_CHUNK);
        let (mut pos, mut scale, mut rot, mut opacity, mut sh) =
            (Vec::new(), Vec::new(), Vec::new(), Vec::new(), Vec::new());
        let mut flush = |ids: &mut Vec<u32>,
                         pos: &mut Vec<f32>,
                         scale: &mut Vec<f32>,
                         rot: &mut Vec<f32>,
                         opacity: &mut Vec<f32>,
                         sh: &mut Vec<f32>,
                         set: &mut ProjectedSet|
         -> anyhow::Result<()> {
            if ids.is_empty() {
                return Ok(());
            }
            let splats = rt.preprocess_chunk(ids, pos, scale, rot, opacity, sh, &cam_params)?;
            set.processed += ids.len();
            set.culled += ids.len() - splats.len();
            set.splats.extend(splats);
            ids.clear();
            pos.clear();
            scale.clear();
            rot.clear();
            opacity.clear();
            sh.clear();
            Ok(())
        };
        for (id, g) in &queue {
            ids.push(*id);
            pos.extend_from_slice(&g.pos.to_array());
            scale.extend_from_slice(&g.scale.to_array());
            rot.extend_from_slice(&g.rot.to_array());
            opacity.push(g.opacity);
            sh.extend_from_slice(&g.sh);
            if ids.len() == PREPROCESS_CHUNK {
                flush(&mut ids, &mut pos, &mut scale, &mut rot, &mut opacity, &mut sh, &mut set)?;
            }
        }
        flush(&mut ids, &mut pos, &mut scale, &mut rot, &mut opacity, &mut sh, &mut set)?;

        // Stereo rasterization (native stereo logic; the per-tile blend
        // math is identical to the HLO kernel — see it_runtime_hlo).
        nebula::render::sort::sort_splats_par(&mut set.splats, cfg.parallelism);
        let n_splats = set.splats.len();
        let out = render_stereo_from_splats(&cam, &set, pl.tile, &cfg, StereoMode::AlphaGated);
        let render_ms = sw.elapsed_ms();
        render_sum += render_ms;

        let done = t_frame + render_ms * 1e-3;
        let display = (done / vsync).ceil() * vsync;
        let mtp = (display - t_frame) * 1e3;
        mtp_sum += mtp;
        if i % 8 == 0 || i + 1 == frames {
            table.row(vec![
                i.to_string(),
                queue.len().to_string(),
                n_splats.to_string(),
                fnum(render_ms, 1),
                fnum(mtp, 1),
                human_bytes(wire),
            ]);
        }
        if i + 1 == frames {
            out.left.write_ppm("collab_left.ppm")?;
            out.right.write_ppm("collab_right.ppm")?;
        }
    }
    table.print();
    let secs = frames as f64 * vsync;
    println!(
        "\n{} frames: mean MTP {:.1} ms, functional render FPS {:.1}, steady bandwidth {}",
        frames,
        mtp_sum / frames as f64,
        1e3 * frames as f64 / render_sum,
        human_bps(wire_total as f64 * 8.0 / secs),
    );
    println!("wrote collab_left.ppm / collab_right.ppm");
    handle.shutdown();
    Ok(())
}
