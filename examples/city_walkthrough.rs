//! City walkthrough: walk a VR headset through a large synthetic city
//! and watch the temporal-aware LoD search + Gaussian management at
//! work — cut stability, Δcut sizes, bandwidth, client memory.
//!
//!     cargo run --release --example city_walkthrough -- [--scene urban]

use nebula::benchkit;
use nebula::compress::{CompressionMode, DeltaCodec, FixedQuantizer, VqTrainer};
use nebula::config::PipelineConfig;
use nebula::lod::{LodSearch, TemporalSearch};
use nebula::manage::protocol::{ClientEndpoint, CloudEndpoint};
use nebula::scene::dataset;
use nebula::util::cli::Args;
use nebula::util::table::{fnum, human_bps, human_bytes, Table};
use nebula::util::Stopwatch;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let spec = dataset(args.get_or("scene", "urban"))?;
    let gaussians = args.get_parse_or("gaussians", 150_000usize);
    let seconds = args.get_parse_or("seconds", 4.0f64);
    let pl = PipelineConfig::default();

    println!("building '{}' at {} Gaussians ...", spec.name, gaussians);
    let tree = nebula::scene::CityGen::new(spec.city_params(gaussians)).build();

    let (lo, hi) = tree.gaussians.bounds();
    let codec = DeltaCodec::new(
        CompressionMode::Quantized,
        FixedQuantizer::for_bounds(lo, hi),
        VqTrainer::default().train(&tree.gaussians.sh),
    );
    let mut cloud = CloudEndpoint::new(&tree, codec, pl.reuse_threshold);
    let mut client = ClientEndpoint::from_init(
        &cloud.scene_init(),
        CompressionMode::Quantized,
        pl.reuse_threshold,
    )?;
    let mut search = TemporalSearch::for_tree(&tree);

    let frames = (seconds * 90.0) as usize;
    let poses = benchkit::walk_trace(&spec, frames);
    let mut table =
        Table::new(vec!["t (s)", "cut", "Δ new", "overlap %", "wire", "client store", "cloud ms"]);
    let mut prev_cut: Option<nebula::lod::Cut> = None;
    let mut total_wire = 0u64;

    for (i, pose) in poses.iter().enumerate().step_by(pl.lod_interval as usize) {
        let sw = Stopwatch::start();
        let cut = search.search(&tree, &benchkit::query_at(pose, &pl));
        let cloud_ms = sw.elapsed_ms();
        let overlap = prev_cut.as_ref().map(|p| p.overlap(&cut) * 100.0).unwrap_or(100.0);
        let msg = cloud.publish_cut(&cut.nodes);
        total_wire += msg.wire_bytes() as u64;
        client.apply(&msg)?;
        if i % 45 == 0 || i + (pl.lod_interval as usize) >= frames {
            table.row(vec![
                fnum(i as f64 / 90.0, 2),
                cut.len().to_string(),
                msg.payload.count.to_string(),
                fnum(overlap, 2),
                human_bytes(msg.wire_bytes() as u64),
                format!("{} ({})", client.store.len(), human_bytes(client.store.byte_size())),
                fnum(cloud_ms, 2),
            ]);
        }
        prev_cut = Some(cut);
    }
    table.print();

    let bw = total_wire as f64 * 8.0 / seconds;
    println!(
        "\nsteady-state bandwidth: {} — vs H.265 Lossy-H VR streaming {} ({}%)",
        human_bps(bw),
        human_bps(
            nebula::net::VideoCodec::vr_stereo(nebula::net::VideoQuality::LossyHigh, 2064, 2208, 90.0)
                .bitrate_bps()
        ),
        fnum(
            bw / nebula::net::VideoCodec::vr_stereo(
                nebula::net::VideoQuality::LossyHigh,
                2064,
                2208,
                90.0
            )
            .bitrate_bps()
                * 100.0,
            1
        )
    );
    Ok(())
}
