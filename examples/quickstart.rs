//! Quickstart: build a city scene, run the LoD search, render one stereo
//! frame, and print what happened.
//!
//!     cargo run --release --example quickstart

use nebula::benchkit;
use nebula::config::PipelineConfig;
use nebula::math::{Intrinsics, StereoCamera};
use nebula::render::raster::RasterConfig;
use nebula::render::stereo::{render_stereo, StereoMode};
use nebula::scene::dataset;
use nebula::util::Stopwatch;

fn main() -> anyhow::Result<()> {
    // 1. A small synthetic city (Tanks&Temples-scale analogue).
    let spec = dataset("tnt")?;
    let sw = Stopwatch::start();
    let tree = nebula::scene::CityGen::new(spec.city_params(40_000)).build();
    println!("scene: {} Gaussians in a LoD tree of depth {} ({:.0} ms)",
        tree.len(), tree.depth(), sw.elapsed_ms());

    // 2. A VR head pose and the LoD cut for it.
    let pl = PipelineConfig::default();
    let pose = benchkit::walk_trace(&spec, 1)[0];
    let cut = benchkit::cut_at(&tree, &pose, &pl);
    println!("LoD cut at the pose: {} Gaussians ({}% of the scene)",
        cut.len(), 100 * cut.len() / tree.len());

    // 3. Render both eyes with the bit-accurate stereo rasterizer.
    let queue = benchkit::queue_for(&tree, &cut);
    let cam = StereoCamera::new(pose, Intrinsics::vr_eye_scaled(8));
    let sw = Stopwatch::start();
    let out = render_stereo(
        &cam,
        &benchkit::queue_refs(&queue),
        pl.sh_degree,
        pl.tile,
        &RasterConfig::default(),
        StereoMode::AlphaGated,
    );
    println!(
        "stereo frame {}x{} per eye in {:.0} ms: {} splats shared across eyes, \
         {} SRU re-projections, {} merge ops",
        cam.intr.width, cam.intr.height, sw.elapsed_ms(),
        out.preprocessed, out.sru_insertions, out.merge_ops
    );
    println!(
        "right eye reused the left eye's preprocessing/sorting; raster pairs: left={} right={}",
        out.stats_left.pairs, out.stats_right.pairs
    );

    out.left.write_ppm("quickstart_left.ppm")?;
    out.right.write_ppm("quickstart_right.ppm")?;
    println!("wrote quickstart_left.ppm / quickstart_right.ppm");
    Ok(())
}
