//! Stereo showcase: renders one frame four ways — Base (independent
//! eyes), WARP, Cicero-proxy, and Nebula's stereo rasterizer — and
//! reports quality + work, reproducing Fig 16's comparison on one pose.
//!
//!     cargo run --release --example stereo_vr -- [--scene m360]

use nebula::benchkit;
use nebula::config::PipelineConfig;
use nebula::math::{Intrinsics, StereoCamera};
use nebula::render::raster::{render_bins, RasterConfig};
use nebula::render::stereo::{render_right_naive, render_stereo_from_splats, StereoMode};
use nebula::render::warp::{depth_map, warp_right, WarpKind};
use nebula::render::{preprocess_records, Parallelism, TileBins};
use nebula::scene::dataset;
use nebula::util::cli::Args;
use nebula::util::table::{fnum, Table};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let spec = dataset(args.get_or("scene", "m360"))?;
    let pl = PipelineConfig::default();
    let tree = nebula::scene::CityGen::new(spec.city_params(args.get_parse_or("gaussians", 80_000))).build();
    let pose = benchkit::walk_trace(&spec, 30)[29];
    let cam = StereoCamera::new(pose, Intrinsics::vr_eye_scaled(pl.res_scale));
    let cfg = RasterConfig::default();

    let cut = benchkit::cut_at(&tree, &pose, &pl);
    let queue = benchkit::queue_for(&tree, &cut);
    let refs = benchkit::queue_refs(&queue);

    // Shared preprocessing (left eye optics, widened FoV).
    let left_cam = cam.left();
    let shared = cam.shared_camera();
    let mut set = preprocess_records(&left_cam, &shared, &refs, pl.sh_degree, Parallelism::auto());
    nebula::render::sort::sort_splats_par(&mut set.splats, Parallelism::auto());

    // Reference right eye (the shared-preprocess pipeline definition).
    let (reference, ref_stats) = render_right_naive(&cam, &set, pl.tile, &cfg);

    // Left image + depth for the warping baselines.
    let bins = TileBins::build_par(
        cam.intr.width,
        cam.intr.height,
        pl.tile,
        0,
        &set.splats,
        Parallelism::auto(),
    );
    let (left_img, _, _) = render_bins(&set.splats, &bins, cam.intr.width, cam.intr.height, &cfg);
    let depth = depth_map(&set.splats, &bins, cam.intr.width, cam.intr.height, &cfg, cam.intr.far);

    let mut table = Table::new(vec!["method", "PSNR dB", "SSIM", "LPIPS-proxy", "right-eye pairs"]);
    let mut report = |name: &str, img: &nebula::render::Image, pairs: u64| {
        table.row(vec![
            name.to_string(),
            fnum(img.psnr(&reference), 1),
            fnum(img.ssim(&reference), 4),
            fnum(img.lpips_proxy(&reference), 4),
            pairs.to_string(),
        ]);
    };

    report("Base (render both eyes)", &reference, ref_stats.pairs);
    let warp = warp_right(&left_img, &depth, &cam, WarpKind::Warp);
    report("WARP [10]", &warp, 0);
    let cicero = warp_right(&left_img, &depth, &cam, WarpKind::Cicero);
    report("Cicero-proxy [27]", &cicero, 0);

    let exact = render_stereo_from_splats(&cam, &set, pl.tile, &cfg, StereoMode::Exact);
    report("Nebula (Exact)", &exact.right, exact.stats_right.pairs);
    let gated = render_stereo_from_splats(&cam, &set, pl.tile, &cfg, StereoMode::AlphaGated);
    report("Nebula (AlphaGated)", &gated.right, gated.stats_right.pairs);

    table.print();
    println!(
        "\nNebula Exact is bitwise-identical to Base (PSNR 99 = our 'identical' cap); \
         AlphaGated trades a sliver of PSNR for {} fewer right-eye pairs.",
        ref_stats.pairs.saturating_sub(gated.stats_right.pairs)
    );
    gated.left.write_ppm("stereo_left.ppm")?;
    gated.right.write_ppm("stereo_right.ppm")?;
    warp.write_ppm("stereo_warp.ppm")?;
    println!("wrote stereo_left.ppm / stereo_right.ppm / stereo_warp.ppm");
    Ok(())
}
